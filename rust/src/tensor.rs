//! Dense row-major f32 matrix substrate.
//!
//! The offline registry has no ndarray/nalgebra, so the whole stack sits
//! on this small, allocation-conscious matrix type.  Everything the
//! paper's math needs is here: matmul (with a cache-blocked kernel for
//! the hot path), transpose, row/column reductions, Frobenius norms, and
//! slicing of stacked `[L, n, c]` captures.

use std::fmt;

/// Row-major dense f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a flat row-major vector (length must be rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "flat data length mismatch");
        Self { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Immutable view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Flat row-major slice.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat vector.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// [`Matrix::transpose`] with output rows split across `threads`
    /// scoped threads (`0` = all cores).
    pub fn transpose_threaded(&self, threads: usize) -> Matrix {
        crate::kernels::par::transpose(self, threads)
    }

    /// Matrix product `self @ rhs` using the cache-blocked i-k-j
    /// kernel of [`crate::kernels::par`] on the calling thread.
    ///
    /// The inner j loop is a contiguous branch-free AXPY over the rhs
    /// row and the output row — it auto-vectorizes.  For sparse-ish
    /// left factors (quantization residuals) see
    /// [`Matrix::matmul_acc_sparse`], which keeps a zero-skip branch.
    pub fn matmul(&self, rhs: &Matrix) -> Matrix {
        self.matmul_threaded(rhs, 1)
    }

    /// [`Matrix::matmul`] with output rows split across `threads`
    /// scoped threads (`0` = all cores).  Bit-identical to the serial
    /// kernel at any thread count.
    pub fn matmul_threaded(&self, rhs: &Matrix, threads: usize) -> Matrix {
        crate::kernels::par::matmul(self, rhs, threads)
    }

    /// `self += a @ b` with the same cache-blocked kernel as [`matmul`].
    pub fn matmul_acc(&mut self, a: &Matrix, b: &Matrix) {
        assert_eq!(a.cols, b.rows, "matmul_acc inner dims: {a:?} @ {b:?}");
        assert_eq!(self.shape(), (a.rows, b.cols), "matmul_acc output shape");
        crate::kernels::par::matmul_acc_into(&mut self.data, a, b, 1);
    }

    /// [`Matrix::matmul_acc`] with a zero-skip on the left factor: the
    /// whole AXPY is skipped when `a[i, k] == 0`.  On dense data the
    /// branch mispredicts and blocks vectorization (use `matmul_acc`);
    /// on sparse-delta factors like `X - Q(X)` — zero wherever a value
    /// sits exactly on the quantization grid — it skips real work.
    /// Used by [`crate::quant::quant_error_fused`] and the fused
    /// analyze kernel.
    pub fn matmul_acc_sparse(&mut self, a: &Matrix, b: &Matrix) {
        assert_eq!(a.cols, b.rows, "matmul_acc inner dims: {a:?} @ {b:?}");
        assert_eq!(self.shape(), (a.rows, b.cols), "matmul_acc output shape");
        crate::kernels::par::matmul_acc_sparse_into(&mut self.data, a, b, 1);
    }

    /// Squared Frobenius norm.
    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Frobenius norm.
    pub fn frob(&self) -> f64 {
        self.frob_sq().sqrt()
    }

    /// Largest absolute entry.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Per-row maximum absolute value.
    pub fn row_abs_max(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| self.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
            .collect()
    }

    /// Per-column maximum absolute value.
    pub fn col_abs_max(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            for (j, &v) in self.row(i).iter().enumerate() {
                if v.abs() > out[j] {
                    out[j] = v.abs();
                }
            }
        }
        out
    }

    /// Per-column Euclidean norm (the paper's activation channel magnitude).
    pub fn col_norms(&self) -> Vec<f64> {
        col_norms_flat(self.as_slice(), self.cols)
    }

    /// Per-row Euclidean norm (the weight channel magnitude along c_in).
    pub fn row_norms(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt())
            .collect()
    }

    /// Elementwise difference `self - rhs`.
    pub fn sub(&self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.shape(), rhs.shape());
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&rhs.data).map(|(a, b)| a - b).collect(),
        }
    }

    /// Scale column `j` of every row by `s[j]` (in place).
    pub fn scale_cols_mut(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.cols);
        for i in 0..self.rows {
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            for (v, &sc) in row.iter_mut().zip(s) {
                *v *= sc;
            }
        }
    }

    /// Scale row `i` by `s[i]` (in place).
    pub fn scale_rows_mut(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.rows);
        for i in 0..self.rows {
            let sc = s[i];
            for v in self.row_mut(i) {
                *v *= sc;
            }
        }
    }
}

/// Squared Frobenius distance `Σ (a_i - b_i)^2` of two equally-shaped
/// row-major buffers, accumulated in f64 — the residual norm both the
/// integer execution path and its equivalence tests compute without
/// materializing a difference matrix.
/// Per-column Euclidean norms of a row-major buffer holding whole rows
/// — the single fold behind [`Matrix::col_norms`] AND
/// [`crate::metrics::quant_difficulty_rows`], so the copying and
/// zero-copy difficulty paths can never drift in accumulation order
/// (the batch-fused serving path's bit-identity pin depends on that
/// being structural, not coincidental).
pub fn col_norms_flat(flat: &[f32], cols: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; cols];
    if cols == 0 {
        return out;
    }
    debug_assert_eq!(flat.len() % cols, 0, "flat buffer must hold whole rows");
    for row in flat.chunks_exact(cols) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += (v as f64) * (v as f64);
        }
    }
    out.iter_mut().for_each(|v| *v = v.sqrt());
    out
}

pub fn frob_dist_sq(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "frob_dist_sq length mismatch");
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x as f64) - (y as f64);
            d * d
        })
        .sum()
}

/// A stack of `layers` matrices of identical shape, e.g. the captured
/// `[L, n, c]` activation tensors, stored contiguously.
#[derive(Clone)]
pub struct Stack {
    layers: usize,
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Stack {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Stack({}x{}x{})", self.layers, self.rows, self.cols)
    }
}

impl Stack {
    pub fn from_vec(layers: usize, rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), layers * rows * cols, "stack flat length mismatch");
        Self { layers, rows, cols, data }
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Copy layer `l` out as a Matrix.
    pub fn layer(&self, l: usize) -> Matrix {
        assert!(l < self.layers, "layer {l} out of range ({})", self.layers);
        let sz = self.rows * self.cols;
        Matrix::from_vec(self.rows, self.cols, self.data[l * sz..(l + 1) * sz].to_vec())
    }

    /// Borrow layer `l` as a flat slice.
    pub fn layer_slice(&self, l: usize) -> &[f32] {
        let sz = self.rows * self.cols;
        &self.data[l * sz..(l + 1) * sz]
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_fn(5, 7, |i, j| (i * 7 + j) as f32);
        let id = Matrix::eye(7);
        assert_eq!(a.matmul(&id), a);
    }

    #[test]
    fn matmul_rectangular_matches_manual() {
        let a = Matrix::from_fn(3, 4, |i, j| (i + j) as f32);
        let b = Matrix::from_fn(4, 2, |i, j| (i as f32) - (j as f32));
        let c = a.matmul(&b);
        for i in 0..3 {
            for j in 0..2 {
                let want: f32 = (0..4).map(|k| a.get(i, k) * b.get(k, j)).sum();
                assert!((c.get(i, j) - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn matmul_acc_accumulates() {
        let a = Matrix::from_fn(3, 4, |i, j| (i + j) as f32);
        let b = Matrix::from_fn(4, 2, |i, j| (i as f32) - (j as f32));
        let mut acc = a.matmul(&b);
        acc.matmul_acc(&a, &b);
        let twice = a.matmul(&b);
        for (got, want) in acc.as_slice().iter().zip(twice.as_slice()) {
            assert!((got - 2.0 * want).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_acc_sparse_matches_dense_kernel() {
        let mut a = Matrix::from_fn(5, 7, |i, j| ((i + j) % 3) as f32 - 1.0);
        a.set(0, 0, 0.0);
        a.set(4, 6, 0.0);
        let b = Matrix::from_fn(7, 3, |i, j| (i as f32) * 0.5 - (j as f32));
        let mut dense = Matrix::zeros(5, 3);
        dense.matmul_acc(&a, &b);
        let mut sparse = Matrix::zeros(5, 3);
        sparse.matmul_acc_sparse(&a, &b);
        for (x, y) in dense.as_slice().iter().zip(sparse.as_slice()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn threaded_entry_points_match_serial() {
        let a = Matrix::from_fn(6, 9, |i, j| (i * 9 + j) as f32 * 0.25);
        let b = Matrix::from_fn(9, 4, |i, j| (i as f32) - 2.0 * (j as f32));
        assert_eq!(a.matmul_threaded(&b, 3).as_slice(), a.matmul(&b).as_slice());
        assert_eq!(a.transpose_threaded(2).as_slice(), a.transpose().as_slice());
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Matrix::from_fn(4, 6, |i, j| (i * 31 + j * 7) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn frob_dist_matches_sub_then_frob() {
        let a = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f32 * 0.5);
        let b = Matrix::from_fn(3, 4, |i, j| (i + j) as f32 - 1.0);
        let want = a.sub(&b).frob_sq();
        let got = frob_dist_sq(a.as_slice(), b.as_slice());
        assert!((want - got).abs() < 1e-9, "{want} vs {got}");
    }

    #[test]
    fn norms_and_maxima() {
        let a = Matrix::from_vec(2, 2, vec![3.0, -4.0, 0.0, 0.0]);
        assert!((a.frob() - 5.0).abs() < 1e-12);
        assert_eq!(a.abs_max(), 4.0);
        assert_eq!(a.row_abs_max(), vec![4.0, 0.0]);
        assert_eq!(a.col_abs_max(), vec![3.0, 4.0]);
        assert!((a.col_norms()[0] - 3.0).abs() < 1e-12);
        assert!((a.row_norms()[0] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn scaling_in_place() {
        let mut a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        a.scale_cols_mut(&[2.0, 0.5]);
        assert_eq!(a.as_slice(), &[2.0, 1.0, 6.0, 2.0]);
        a.scale_rows_mut(&[1.0, 10.0]);
        assert_eq!(a.as_slice(), &[2.0, 1.0, 60.0, 20.0]);
    }

    #[test]
    fn stack_layer_extraction() {
        let data: Vec<f32> = (0..24).map(|v| v as f32).collect();
        let s = Stack::from_vec(2, 3, 4, data);
        let l1 = s.layer(1);
        assert_eq!(l1.get(0, 0), 12.0);
        assert_eq!(l1.get(2, 3), 23.0);
        assert_eq!(s.layer_slice(0).len(), 12);
    }

    #[test]
    #[should_panic]
    fn stack_layer_out_of_range_panics() {
        let s = Stack::from_vec(1, 2, 2, vec![0.0; 4]);
        let _ = s.layer(1);
    }
}
