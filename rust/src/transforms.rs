//! Equivalent transformations — rust-native mirror of Eq. 3–5.
//!
//! Hadamard construction (Sylvester + Paley-I + Kronecker, identical to
//! `python/compile/hadamard.py`), SmoothQuant channel scaling, Hadamard
//! rotation, and the paper's smooth-rotation hybrid.  The PJRT artifacts
//! bake the same matrices as constants; the integration tests assert the
//! two paths agree.
//!
//! Rotation application is routed through [`Rotation`]: whenever the
//! width factors as `2^p · paley` (every constructible width does), the
//! O(d log d) fast Walsh–Hadamard plan of [`crate::kernels::fwht`]
//! replaces the dense `X @ H` matmul, and [`RotationCache`] reuses one
//! rotation per width across requests with hit/miss counters for the
//! serving metrics.

use crate::kernels::fwht::FwhtPlan;
use crate::metrics::CacheStats;
use crate::tensor::Matrix;

/// Transform mode, in canonical artifact order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mode {
    None,
    Smooth,
    Rotate,
    SmoothRotate,
}

impl Mode {
    pub const ALL: [Mode; 4] = [Mode::None, Mode::Smooth, Mode::Rotate, Mode::SmoothRotate];

    pub fn name(self) -> &'static str {
        match self {
            Mode::None => "none",
            Mode::Smooth => "smooth",
            Mode::Rotate => "rotate",
            Mode::SmoothRotate => "smooth_rotate",
        }
    }

    pub fn from_name(s: &str) -> Option<Mode> {
        Mode::ALL.into_iter().find(|m| m.name() == s)
    }

    pub fn index(self) -> usize {
        Mode::ALL.iter().position(|&m| m == self).unwrap()
    }
}

// ---------------------------------------------------------------------
// Hadamard construction
// ---------------------------------------------------------------------

/// Sylvester Hadamard matrix of size d = 2^p (entries ±1).
pub fn sylvester(d: usize) -> Result<Matrix, String> {
    if d == 0 || (d & (d - 1)) != 0 {
        return Err(format!("Sylvester construction needs a power of two, got {d}"));
    }
    let mut h = Matrix::from_vec(1, 1, vec![1.0]);
    while h.rows() < d {
        let n = h.rows();
        let mut next = Matrix::zeros(2 * n, 2 * n);
        for i in 0..n {
            for j in 0..n {
                let v = h.get(i, j);
                next.set(i, j, v);
                next.set(i, j + n, v);
                next.set(i + n, j, v);
                next.set(i + n, j + n, -v);
            }
        }
        h = next;
    }
    Ok(h)
}

fn is_prime(q: usize) -> bool {
    if q < 2 {
        return false;
    }
    let mut p = 2;
    while p * p <= q {
        if q % p == 0 {
            return false;
        }
        p += 1;
    }
    true
}

/// Paley-I Hadamard matrix of size q+1 for prime q with q % 4 == 3.
pub fn paley1(q: usize) -> Result<Matrix, String> {
    if q % 4 != 3 {
        return Err(format!("Paley-I needs q % 4 == 3, got {q}"));
    }
    if !is_prime(q) {
        return Err(format!("Paley-I implemented for prime q only, got {q}"));
    }
    // quadratic residue character chi
    let mut chi = vec![0.0f32; q];
    let mut residues = vec![false; q];
    for x in 1..q {
        residues[(x * x) % q] = true;
    }
    for (a, c) in chi.iter_mut().enumerate().skip(1) {
        *c = if residues[a] { 1.0 } else { -1.0 };
    }
    let d = q + 1;
    let mut h = Matrix::zeros(d, d);
    // H = I + S, S = [[0, 1^T], [-1, Q]]
    for j in 1..d {
        h.set(0, j, 1.0);
        h.set(j, 0, -1.0);
    }
    for i in 0..q {
        for j in 0..q {
            h.set(i + 1, j + 1, chi[(j + q - i) % q]);
        }
    }
    for i in 0..d {
        h.set(i, i, h.get(i, i) + 1.0);
    }
    Ok(h)
}

/// Kronecker product (used to compose Sylvester with a Paley base).
pub fn kron(a: &Matrix, b: &Matrix) -> Matrix {
    let (ar, ac) = a.shape();
    let (br, bc) = b.shape();
    let mut out = Matrix::zeros(ar * br, ac * bc);
    for i in 0..ar {
        for j in 0..ac {
            let av = a.get(i, j);
            if av == 0.0 {
                continue;
            }
            for bi in 0..br {
                for bj in 0..bc {
                    out.set(i * br + bi, j * bc + bj, av * b.get(bi, bj));
                }
            }
        }
    }
    out
}

/// Paley-I base orders we can build directly (order -> q).
const PALEY_ORDERS: [(usize, usize); 8] =
    [(4, 3), (12, 11), (20, 19), (24, 23), (28, 27), (44, 43), (48, 47), (60, 59)];

/// How width `d` factors for the crate's Hadamard construction:
/// `Some((pow2, q))` means `H_d = sylvester(pow2) ⊗ paley1(q)` (with
/// `q == 0` encoding a pure Sylvester width, `H_d = sylvester(d)`);
/// `None` means no construction is available.  Shared by the dense
/// [`hadamard`] builder and the [`crate::kernels::fwht`] fast path, so
/// the two can never disagree about which `H_d` they implement.
pub fn hadamard_factor(d: usize) -> Option<(usize, usize)> {
    if d >= 1 && d.is_power_of_two() {
        return Some((d, 0));
    }
    let mut orders = PALEY_ORDERS;
    orders.sort_by(|a, b| b.0.cmp(&a.0));
    for (order, q) in orders {
        if d % order == 0 {
            let pow2 = d / order;
            if pow2 >= 1 && pow2.is_power_of_two() {
                return Some((pow2, q));
            }
        }
    }
    None
}

/// Unnormalized Hadamard matrix of size d (Sylvester or Kronecker/Paley).
pub fn hadamard(d: usize) -> Result<Matrix, String> {
    match hadamard_factor(d) {
        Some((pow2, 0)) => sylvester(pow2),
        Some((1, q)) => paley1(q),
        Some((pow2, q)) => Ok(kron(&sylvester(pow2)?, &paley1(q)?)),
        None => Err(format!("no Hadamard construction available for d={d}")),
    }
}

/// Orthonormal rotation R = H / sqrt(d) (Eq. 5).
pub fn rotation(d: usize) -> Result<Matrix, String> {
    let mut h = hadamard(d)?;
    let scale = 1.0 / (d as f32).sqrt();
    for v in h.as_mut_slice() {
        *v *= scale;
    }
    Ok(h)
}

/// One applicable rotation `R = H_d / sqrt(d)` for a fixed width.
///
/// Every width the crate can construct a Hadamard for factors as
/// Sylvester ⊗ Paley, so [`Rotation::build`] always yields the
/// O(d log d) in-place [`FwhtPlan`] — no dense `H` is ever
/// materialized on that path, and [`Rotation::Dense`] is today only
/// reachable by constructing the variant directly (e.g. a future
/// non-Paley construction, or a caller that already holds a dense
/// `R`).  Both variants implement the same apply surface, so such a
/// width would drop in without touching the engine.
#[derive(Clone, Debug)]
pub enum Rotation {
    /// Fast Walsh–Hadamard plan: O(d log d) per row, in place.
    Fwht(FwhtPlan),
    /// Dense orthonormal matrix: O(d^2) per row.
    Dense(Matrix),
}

impl Rotation {
    /// Build the rotation for width `d` — FWHT whenever the width
    /// factors as `2^p · paley`, else the dense construction (which
    /// errors for exactly the same widths the factorization rejects).
    pub fn build(d: usize) -> Result<Rotation, String> {
        match FwhtPlan::new(d) {
            Some(plan) => Ok(Rotation::Fwht(plan)),
            None => Ok(Rotation::Dense(rotation(d)?)),
        }
    }

    /// The width this rotation applies to.
    pub fn dim(&self) -> usize {
        match self {
            Rotation::Fwht(p) => p.dim(),
            Rotation::Dense(m) => m.rows(),
        }
    }

    /// Whether this rotation runs through the fast O(d log d) path.
    pub fn is_fwht(&self) -> bool {
        matches!(self, Rotation::Fwht(_))
    }

    /// `X <- X @ R`, in place over X's rows, fanned out over `threads`.
    pub fn apply_rows(&self, x: &mut Matrix, threads: usize) {
        match self {
            Rotation::Fwht(p) => p.apply_matrix(x, threads),
            Rotation::Dense(r) => *x = crate::kernels::par::matmul(x, r, threads),
        }
    }

    /// `X @ R` into a fresh matrix (Eq. 3's activation side).
    pub fn apply_right(&self, x: &Matrix, threads: usize) -> Matrix {
        let mut out = x.clone();
        self.apply_rows(&mut out, threads);
        out
    }

    /// `R^T @ W` (Eq. 3's weight side) — computed as `(W^T R)^T`, so
    /// the FWHT path needs two transposes and zero dense matmuls.
    pub fn apply_left_t(&self, w: &Matrix, threads: usize) -> Matrix {
        match self {
            Rotation::Fwht(_) => {
                let mut wt = crate::kernels::par::transpose(w, threads);
                self.apply_rows(&mut wt, threads);
                crate::kernels::par::transpose(&wt, threads)
            }
            Rotation::Dense(r) => {
                crate::kernels::par::matmul(&crate::kernels::par::transpose(r, threads), w, threads)
            }
        }
    }
}

/// Cache of rotations keyed by dimension, with hit/miss counters.
///
/// Building a rotation (Hadamard factorization, Paley base, or the
/// dense fallback) is identical for every request of the same width,
/// so the serving core's batch executors build each rotation once and
/// reuse it across jobs (see [`crate::serve::NativeBatchExecutor`]);
/// the counters surface in the serve summary line.
///
/// ```
/// use smoothrot::transforms::RotationCache;
/// let mut cache = RotationCache::new();
/// assert_eq!(cache.get(8).unwrap().dim(), 8);
/// assert!(cache.get(8).unwrap().is_fwht());
/// // the second lookup was served from the cache
/// let stats = cache.stats();
/// assert_eq!((stats.hits, stats.misses), (1, 1));
/// ```
#[derive(Debug, Default)]
pub struct RotationCache {
    map: std::collections::BTreeMap<usize, Rotation>,
    hits: u64,
    misses: u64,
}

impl RotationCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// The rotation for dimension `d`, constructing it on first use.
    pub fn get(&mut self, d: usize) -> Result<&Rotation, String> {
        if self.map.contains_key(&d) {
            self.hits += 1;
        } else {
            self.misses += 1;
            let r = Rotation::build(d)?;
            self.map.insert(d, r);
        }
        Ok(&self.map[&d])
    }

    /// Number of distinct dimensions cached so far.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Hit/miss counters since creation.  A failed build counts as a
    /// miss (each retry re-attempts the construction).
    pub fn stats(&self) -> CacheStats {
        CacheStats { hits: self.hits, misses: self.misses }
    }
}

/// Check entries are ±1 and H H^T = d I.
pub fn is_hadamard(h: &Matrix) -> bool {
    let (r, c) = h.shape();
    if r != c {
        return false;
    }
    if h.as_slice().iter().any(|&v| (v.abs() - 1.0).abs() > 1e-6) {
        return false;
    }
    let prod = h.matmul(&h.transpose());
    for i in 0..r {
        for j in 0..c {
            let want = if i == j { r as f32 } else { 0.0 };
            if (prod.get(i, j) - want).abs() > 1e-3 {
                return false;
            }
        }
    }
    true
}

// ---------------------------------------------------------------------
// Smoothing / rotation application
// ---------------------------------------------------------------------

const EPS: f32 = 1e-12;

/// SmoothQuant migration factor s_j (Eq. 4) from precomputed
/// per-channel absolute maxima, zero-safe.  The maxima may come from a
/// one-shot matrix pass ([`smooth_scales`]) or from a streaming
/// calibration accumulator ([`crate::calib::stats::ChannelStats`]) —
/// identical maxima yield bit-identical scales either way.
pub fn smooth_scales_from_max(xmax: &[f32], wmax: &[f32], alpha: f32) -> Vec<f32> {
    assert_eq!(xmax.len(), wmax.len(), "smooth scales need matching channel counts");
    xmax.iter()
        .zip(wmax)
        .map(|(&xm, &wm)| xm.max(EPS).powf(alpha) / wm.max(EPS).powf(1.0 - alpha))
        .collect()
}

/// Per-input-channel absolute maxima of a weight matrix (Eq. 4's
/// `max|W_j|`, channels indexed by row).
pub fn weight_row_abs_max(w: &Matrix) -> Vec<f32> {
    let mut wmax = vec![0.0f32; w.rows()];
    for i in 0..w.rows() {
        wmax[i] = w.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    }
    wmax
}

/// SmoothQuant migration factor s_j (Eq. 4), zero-safe.
pub fn smooth_scales(x: &Matrix, w: &Matrix, alpha: f32) -> Vec<f32> {
    smooth_scales_from_max(&x.col_abs_max(), &weight_row_abs_max(w), alpha)
}

/// Apply a precomputed migration vector: X/s per column, s*W per row.
pub fn smooth_apply(x: &Matrix, w: &Matrix, s: &[f32]) -> (Matrix, Matrix) {
    let mut xh = x.clone();
    let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
    xh.scale_cols_mut(&inv);
    let mut wh = w.clone();
    wh.scale_rows_mut(s);
    (xh, wh)
}

/// Apply `mode` to (X, W) and return (X_hat, W_hat) (Eq. 3).
pub fn apply(mode: Mode, x: &Matrix, w: &Matrix, alpha: f32) -> Result<(Matrix, Matrix), String> {
    let mut cache = RotationCache::new();
    apply_cached(mode, x, w, alpha, &mut cache)
}

/// [`apply`] with rotation reuse: rotating modes take R from `cache`
/// instead of rebuilding the Hadamard matrix per call.  This is the hot
/// path for batched serving, where every job in a coalesced batch shares
/// the same activation width.
pub fn apply_cached(
    mode: Mode,
    x: &Matrix,
    w: &Matrix,
    alpha: f32,
    cache: &mut RotationCache,
) -> Result<(Matrix, Matrix), String> {
    match mode {
        Mode::None => Ok((x.clone(), w.clone())),
        Mode::Smooth => {
            let s = smooth_scales(x, w, alpha);
            Ok(smooth_apply(x, w, &s))
        }
        Mode::Rotate => {
            let r = cache.get(x.cols())?;
            Ok((r.apply_right(x, 1), r.apply_left_t(w, 1)))
        }
        Mode::SmoothRotate => {
            let s = smooth_scales(x, w, alpha);
            let (mut xs, ws) = smooth_apply(x, w, &s);
            let r = cache.get(x.cols())?;
            r.apply_rows(&mut xs, 1);
            Ok((xs, r.apply_left_t(&ws, 1)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rows, cols, rng.normals_f32(rows * cols))
    }

    #[test]
    fn sylvester_small_sizes() {
        for d in [1usize, 2, 4, 8, 16, 64, 256] {
            assert!(is_hadamard(&sylvester(d).unwrap()), "d={d}");
        }
    }

    #[test]
    fn sylvester_rejects_non_pow2() {
        assert!(sylvester(12).is_err());
        assert!(sylvester(0).is_err());
    }

    #[test]
    fn paley_known_orders() {
        for q in [3usize, 7, 11, 19, 23, 43, 47, 59] {
            assert!(is_hadamard(&paley1(q).unwrap()), "q={q}");
        }
    }

    #[test]
    fn paley_rejects_bad_q() {
        assert!(paley1(5).is_err());
        assert!(paley1(15).is_err());
    }

    #[test]
    fn hadamard_kronecker_704() {
        assert!(is_hadamard(&hadamard(704).unwrap()));
        assert!(is_hadamard(&hadamard(44).unwrap()));
        assert!(is_hadamard(&hadamard(88).unwrap()));
    }

    #[test]
    fn hadamard_unsupported() {
        assert!(hadamard(172).is_err());
        assert!(hadamard(6).is_err());
    }

    #[test]
    fn rotation_orthonormal() {
        for d in [64usize, 44] {
            let r = rotation(d).unwrap();
            let prod = r.matmul(&r.transpose());
            for i in 0..d {
                for j in 0..d {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((prod.get(i, j) - want).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn all_modes_preserve_product() {
        let x = rand_matrix(16, 64, 1);
        let w = rand_matrix(64, 8, 2);
        let y = x.matmul(&w);
        for mode in Mode::ALL {
            let (xh, wh) = apply(mode, &x, &w, 0.5).unwrap();
            let yh = xh.matmul(&wh);
            let scale = y.abs_max().max(1.0);
            for (a, b) in y.as_slice().iter().zip(yh.as_slice()) {
                assert!((a - b).abs() / scale < 1e-4, "{mode:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn smooth_scales_from_max_matches_matrix_path() {
        let x = rand_matrix(16, 32, 30);
        let w = rand_matrix(32, 8, 31);
        let via_matrix = smooth_scales(&x, &w, 0.65);
        let via_max = smooth_scales_from_max(&x.col_abs_max(), &weight_row_abs_max(&w), 0.65);
        assert_eq!(via_matrix, via_max, "identical maxima must give bit-identical scales");
    }

    #[test]
    fn smooth_equalizes_maxima_at_half() {
        let x = rand_matrix(16, 32, 3);
        let w = rand_matrix(32, 8, 4);
        let s = smooth_scales(&x, &w, 0.5);
        let (xh, wh) = smooth_apply(&x, &w, &s);
        let xmax = x.col_abs_max();
        let mut wmax = vec![0.0f32; 32];
        for i in 0..32 {
            wmax[i] = w.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        }
        let xhmax = xh.col_abs_max();
        for j in 0..32 {
            let want = (xmax[j] * wmax[j]).sqrt();
            assert!((xhmax[j] - want).abs() / want < 1e-4);
            let whmax = wh.row(j).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            assert!((whmax - want).abs() / want < 1e-4);
        }
    }

    #[test]
    fn rotation_preserves_frobenius() {
        let x = rand_matrix(8, 64, 5);
        let w = rand_matrix(64, 8, 6);
        let (xh, wh) = apply(Mode::Rotate, &x, &w, 0.5).unwrap();
        assert!((xh.frob() - x.frob()).abs() / x.frob() < 1e-5);
        assert!((wh.frob() - w.frob()).abs() / w.frob() < 1e-5);
    }

    #[test]
    fn mode_names_roundtrip() {
        for m in Mode::ALL {
            assert_eq!(Mode::from_name(m.name()), Some(m));
        }
        assert_eq!(Mode::from_name("bogus"), None);
        assert_eq!(Mode::SmoothRotate.index(), 3);
    }

    #[test]
    fn apply_cached_matches_apply() {
        let x = rand_matrix(8, 64, 7);
        let w = rand_matrix(64, 8, 8);
        let mut cache = RotationCache::new();
        for mode in Mode::ALL {
            let (xa, wa) = apply(mode, &x, &w, 0.5).unwrap();
            let (xb, wb) = apply_cached(mode, &x, &w, 0.5, &mut cache).unwrap();
            assert_eq!(xa.as_slice(), xb.as_slice(), "{mode:?} X");
            assert_eq!(wa.as_slice(), wb.as_slice(), "{mode:?} W");
        }
        // one width -> one cached rotation, reused across both rotating modes
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn hadamard_factor_agrees_with_construction() {
        assert_eq!(hadamard_factor(64), Some((64, 0)));
        assert_eq!(hadamard_factor(44), Some((1, 43)));
        assert_eq!(hadamard_factor(704), Some((16, 43)));
        assert_eq!(hadamard_factor(6), None);
        assert_eq!(hadamard_factor(0), None);
        for d in [1usize, 2, 44, 64, 88, 704] {
            assert_eq!(hadamard(d).unwrap().shape(), (d, d), "d={d}");
        }
    }

    #[test]
    fn rotation_enum_matches_dense_rotation() {
        for d in [16usize, 44, 64] {
            let rot = Rotation::build(d).unwrap();
            assert!(rot.is_fwht(), "constructible width must take the FWHT path");
            assert_eq!(rot.dim(), d);
            let x = rand_matrix(5, d, d as u64);
            let w = rand_matrix(d, 7, 1000 + d as u64);
            let r = rotation(d).unwrap();
            let xr_dense = x.matmul(&r);
            let xr_fast = rot.apply_right(&x, 2);
            let scale = xr_dense.abs_max().max(1.0);
            for (a, b) in xr_dense.as_slice().iter().zip(xr_fast.as_slice()) {
                assert!((a - b).abs() / scale < 1e-4, "X side d={d}: {a} vs {b}");
            }
            let wr_dense = r.transpose().matmul(&w);
            let wr_fast = rot.apply_left_t(&w, 2);
            let scale = wr_dense.abs_max().max(1.0);
            for (a, b) in wr_dense.as_slice().iter().zip(wr_fast.as_slice()) {
                assert!((a - b).abs() / scale < 1e-4, "W side d={d}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rotation_cache_counts_hits_and_misses() {
        let mut cache = RotationCache::new();
        cache.get(16).unwrap();
        cache.get(16).unwrap();
        cache.get(64).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 2));
        assert_eq!(cache.len(), 2);
        // a failed build counts as a miss and caches nothing
        assert!(cache.get(6).is_err());
        assert_eq!(cache.stats().misses, 3);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn kron_dims_and_values() {
        let a = Matrix::from_vec(2, 2, vec![1.0, -1.0, 0.0, 2.0]);
        let b = Matrix::eye(3);
        let k = kron(&a, &b);
        assert_eq!(k.shape(), (6, 6));
        assert_eq!(k.get(0, 0), 1.0);
        assert_eq!(k.get(0, 3), -1.0);
        assert_eq!(k.get(3, 3), 2.0);
    }
}
