//! The calibrate-vs-analyze equivalence pin.
//!
//! `smoothrot calibrate` must choose, per (module, layer), exactly the
//! transform `policy::recommend` derives from an `analyze` sweep of the
//! same workload (both sit on `calib::search::choose_mode`), and the
//! plan-driven serving path must reproduce the full analyze's numbers
//! for the planned mode bit-for-bit — zero per-request transform
//! search, zero drift.

use std::sync::Arc;

use smoothrot::calib::registry::PlanRegistry;
use smoothrot::calib::search::{search_layer, SearchConfig};
use smoothrot::calib::stats::LayerCollector;
use smoothrot::coordinator::Job;
use smoothrot::kernels::fused::analyze_all_modes;
use smoothrot::kernels::workspace::Workspace;
use smoothrot::pipeline::{calibrate_synthetic, check_plan_matches_policy, CalibrateConfig};
use smoothrot::policy::{recommend, PolicyConfig};
use smoothrot::serve::NativeBatchExecutor;
use smoothrot::transforms::{Mode, RotationCache};

#[test]
fn calibrated_plan_matches_policy_recommend_on_the_same_workload() {
    let cfg = CalibrateConfig {
        layers: 4,
        rows_per_batch: 24,
        batches: 2,
        shards: 2,
        max_sample_rows: 0, // full retention: the pin is exact
        seed: 77,
        search: SearchConfig::default(),
    };
    let run = calibrate_synthetic(&cfg).unwrap();
    assert_eq!(run.plan.entries.len(), 4 * smoothrot::MODULES.len());
    check_plan_matches_policy(&run).unwrap();

    // the explicit cell-by-cell form of the same pin
    let policy = recommend(&run.grid, PolicyConfig { sr_margin: cfg.search.sr_margin });
    for (module, modes) in &policy.cells {
        for (layer, want) in modes.iter().enumerate() {
            let entry = run.plan.get(module, layer, 4).unwrap();
            assert_eq!(
                entry.mode, *want,
                "{module} layer {layer}: calibrate chose {}, analyze-derived policy chose {}",
                entry.mode.name(),
                want.name()
            );
        }
    }
    // the synth down_proj stream plants massive spikes at layer 1 —
    // the paper's Sec. V conclusion must emerge from calibration too
    assert_eq!(run.plan.get("down_proj", 1, 4).unwrap().mode, Mode::SmoothRotate);
}

#[test]
fn sharded_collection_changes_no_decision() {
    let base = CalibrateConfig {
        layers: 2,
        rows_per_batch: 16,
        batches: 4,
        shards: 1,
        max_sample_rows: 0,
        seed: 5,
        search: SearchConfig::default(),
    };
    let single = calibrate_synthetic(&base).unwrap();
    let sharded = calibrate_synthetic(&CalibrateConfig { shards: 4, ..base.clone() }).unwrap();
    // contiguous shard ranges merged in order reproduce the
    // single-stream sample and abs-max exactly, so every decision
    // (mode, alpha, error, smoothing vector) is bit-identical; only
    // the Welford-derived difficulty may differ by merge-order ulps
    assert_eq!(single.plan.entries.len(), sharded.plan.entries.len());
    for (a, b) in single.plan.entries.iter().zip(&sharded.plan.entries) {
        assert_eq!((a.module.as_str(), a.layer, a.bits), (b.module.as_str(), b.layer, b.bits));
        assert_eq!(a.mode, b.mode, "{} layer {}", a.module, a.layer);
        assert_eq!(a.alpha, b.alpha);
        assert_eq!(a.predicted_error, b.predicted_error, "{} layer {}", a.module, a.layer);
        assert_eq!(a.smooth, b.smooth, "{} layer {}", a.module, a.layer);
        assert_eq!(a.difficulty_after, b.difficulty_after);
        let rel = (a.difficulty_before - b.difficulty_before).abs()
            / a.difficulty_before.abs().max(1e-12);
        assert!(rel < 1e-9, "{} layer {}: difficulty drifted {rel}", a.module, a.layer);
    }
    // and re-running with the same shard count is fully deterministic
    let again = calibrate_synthetic(&CalibrateConfig { shards: 4, ..base }).unwrap();
    assert_eq!(again.plan.entries, sharded.plan.entries);
    assert_eq!(again.plan.content_hash(), sharded.plan.content_hash());
}

#[test]
fn plan_driven_serving_reproduces_the_full_analyze_numbers() {
    // calibrate one massive-outlier cell end-to-end through a plan
    // *file* and the registry, then serve a request over the same
    // activations: the planned path must equal the full analyze's
    // numbers for the chosen mode exactly.
    let (mut spec, c_out) = smoothrot::synth::module_stream("down_proj", 9).unwrap();
    spec.n_tokens = 32;
    let layer = 1; // massive-spike layer
    let x = spec.layer(layer);
    let w = spec.weight(c_out, layer);

    let mut collector = LayerCollector::new(x.cols(), 0);
    collector.observe(&x).unwrap();
    let mut cache = RotationCache::new();
    let mut ws = Workspace::new();
    let found = search_layer(
        "down_proj",
        layer,
        &collector,
        &w,
        &SearchConfig::default(),
        &mut cache,
        &mut ws,
    )
    .unwrap();
    let plan = smoothrot::calib::plan::QuantPlan {
        provenance: smoothrot::calib::plan::Provenance::default(),
        entries: found.entries,
    };
    let mode = plan.get("down_proj", layer, 4).unwrap().mode;

    let dir = std::env::temp_dir().join("smoothrot_equivalence_test");
    let path = dir.join("plan.json");
    plan.save(&path).unwrap();
    let registry = Arc::new(PlanRegistry::load(&path).unwrap());

    let mut exec = NativeBatchExecutor::with_plan(Arc::clone(&registry), 1);
    let job = Job {
        id: 0,
        layer,
        module: "down_proj",
        x: x.clone(),
        w: w.clone(),
        alpha: 0.5,
        bits: 4,
    };
    let served = exec.run(&job).unwrap();
    let mut cache2 = RotationCache::new();
    let mut ws2 = Workspace::new();
    let full = analyze_all_modes(&x, &w, 4, 0.5, &mut cache2, &mut ws2, 1).unwrap();

    let i = mode.index();
    assert_eq!(served.errors[i], full.errors[i], "planned error must be exact, not close");
    assert_eq!(served.act_difficulty[i], full.act_difficulty[i]);
    assert_eq!(served.act_absmax[i], full.act_absmax[i]);
    for j in 0..4 {
        if j != i {
            assert!(served.errors[j].is_infinite(), "only the planned mode may be evaluated");
        }
    }
    assert_eq!(registry.stats(), (1, 0), "the request must be answered by the plan");
    std::fs::remove_dir_all(&dir).ok();
}
