//! Wire-level chaos: the HTTP front-end under deterministic fault
//! injection ([`smoothrot::faults`] sites `net.*`) and hostile clients.
//!
//! The contracts this suite pins, per ISSUE 10:
//!
//! * a malformed request gets a **named 4xx** (taxonomy error body),
//!   never a panic, and the server keeps serving afterwards;
//! * a connection dropped mid-stream (`net.conn_drop`) loses only its
//!   own response — its batchmates complete **bit-identically** to a
//!   fault-free run, and nothing is quarantined;
//! * under queue pressure the server sheds with **429 + positive
//!   Retry-After** instead of growing the queue;
//! * a graceful drain racing a plan hot-swap drops **zero** in-flight
//!   responses.
//!
//! Every test that arms the process-global fault plan holds
//! [`faults::exclusive`] for its whole body and disarms on drop, so
//! this suite is safe under cargo's parallel test runner.

use smoothrot::calib::plan::{PlanEntry, Provenance, QuantPlan};
use smoothrot::calib::registry::PlanRegistry;
use smoothrot::faults;
use smoothrot::jsonio::{self, Json};
use smoothrot::serve::net::{synth_job_builder, CoreServer, NetConfig, NetServer};
use smoothrot::serve::proto;
use smoothrot::serve::{ExecMode, NativeBatchExecutor, ServeConfig};
use smoothrot::transforms::Mode;
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Disarm the global fault plan when dropped — keeps a failed
/// assertion from leaking an armed plan into the next test.
struct Disarm;

impl Drop for Disarm {
    fn drop(&mut self) {
        faults::disarm();
    }
}

const STREAM_SEED: u64 = 2025;

fn tiny_server(cfg: ServeConfig, net: NetConfig) -> NetServer {
    let (core, rx) =
        CoreServer::start_with_telemetry(cfg, None, None, |_| Ok(NativeBatchExecutor::new()));
    NetServer::start(net, core, rx, None, synth_job_builder(STREAM_SEED)).unwrap()
}

fn post(addr: SocketAddr, target: &str, body: &[u8]) -> proto::HttpResponse {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut w = BufWriter::new(stream.try_clone().unwrap());
    proto::write_request(&mut w, "POST", target, body).unwrap();
    w.flush().unwrap();
    proto::read_response(&mut BufReader::new(stream)).unwrap()
}

fn get(addr: SocketAddr, target: &str) -> proto::HttpResponse {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut w = BufWriter::new(stream.try_clone().unwrap());
    proto::write_request(&mut w, "GET", target, b"").unwrap();
    w.flush().unwrap();
    proto::read_response(&mut BufReader::new(stream)).unwrap()
}

/// The named error in a taxonomy error body.
fn error_name(resp: &proto::HttpResponse) -> String {
    let doc = jsonio::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    doc.get("error").and_then(Json::as_str).unwrap().to_string()
}

#[test]
fn malformed_requests_get_named_4xx_and_the_server_keeps_serving() {
    let server = tiny_server(
        ServeConfig { workers: 1, ..ServeConfig::default() },
        NetConfig::default(),
    );
    let addr = server.addr();

    // garbage request line → 400 bad_request_line
    {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = stream.try_clone().unwrap();
        w.write_all(b"TOTAL GARBAGE\r\n\r\n").unwrap();
        let resp = proto::read_response(&mut BufReader::new(stream)).unwrap();
        assert_eq!(resp.status, 400);
        assert_eq!(error_name(&resp), "bad_request_line");
    }

    // body that is not JSON → 400 body_not_json
    let resp = post(addr, "/analyze", b"not json at all");
    assert_eq!(resp.status, 400);
    assert_eq!(error_name(&resp), "body_not_json");

    // well-formed JSON, unknown module → 400 unknown_module
    let resp = post(addr, "/analyze", br#"{"module":"v_proj","layer":0}"#);
    assert_eq!(resp.status, 400);
    assert_eq!(error_name(&resp), "unknown_module");

    // declared body larger than the cap → 413 body_too_large
    {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = stream.try_clone().unwrap();
        w.write_all(
            format!(
                "POST /analyze HTTP/1.1\r\ncontent-length: {}\r\n\r\n",
                proto::DEFAULT_MAX_BODY + 1
            )
            .as_bytes(),
        )
        .unwrap();
        let resp = proto::read_response(&mut BufReader::new(stream)).unwrap();
        assert_eq!(resp.status, 413);
        assert_eq!(error_name(&resp), "body_too_large");
    }

    // after all of that abuse, a clean request still completes
    assert_eq!(get(addr, "/healthz").status, 200);
    let ok = post(addr, "/analyze", br#"{"module":"k_proj","layer":0,"rows":4,"seed":7}"#);
    assert_eq!(ok.status, 200);

    let stats = server.stats();
    assert_eq!(stats.status(400), 2);
    assert_eq!(stats.status(413), 1);
    // healthz + the analyze envelope + the analyze result line
    assert_eq!(stats.status(200), 3);
    server.drain();
    let m = server.wait().unwrap();
    assert_eq!(m.completed, 1);
    assert_eq!(m.errors, 0, "malformed requests never reach a worker");
}

/// Post one spec and collect the per-mode `errors_bits` of its single
/// result line, or `None` if the connection died mid-stream.
fn analyze_bits(addr: SocketAddr, spec_json: &str) -> Option<Vec<String>> {
    let stream = TcpStream::connect(addr).ok()?;
    stream.set_read_timeout(Some(Duration::from_secs(30))).ok()?;
    let mut w = BufWriter::new(stream.try_clone().unwrap());
    proto::write_request(&mut w, "POST", "/analyze", spec_json.as_bytes()).ok()?;
    w.flush().ok()?;
    let resp = proto::read_response(&mut BufReader::new(stream)).ok()?;
    if resp.status != 200 {
        return None;
    }
    let text = String::from_utf8(resp.body).ok()?;
    let line = jsonio::parse(text.lines().next()?).ok()?;
    if line.get("status").and_then(Json::as_usize) != Some(200) {
        return None;
    }
    Some(
        line.get("errors_bits")?
            .as_arr()?
            .iter()
            .filter_map(|j| j.as_str().map(str::to_string))
            .collect(),
    )
}

/// One serving run: `n` concurrent clients against a paused core (so
/// their jobs batch together), then drain.  Returns each client's
/// result and the end-of-run (metrics, wire stats).
fn batched_run(
    n: usize,
    specs: &[String],
) -> (Vec<Option<Vec<String>>>, smoothrot::serve::ServeMetrics, Arc<smoothrot::serve::net::NetStats>)
{
    let server = tiny_server(
        ServeConfig { workers: 1, max_batch: 8, queue_depth: 64, paused: true, ..Default::default() },
        NetConfig::default(),
    );
    let addr = server.addr();
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let spec = specs[i].clone();
            std::thread::spawn(move || analyze_bits(addr, &spec))
        })
        .collect();
    // all n jobs are in the paused queue once every client has either
    // submitted (blocked on its response) or been torn down — give the
    // submissions a moment, then drain to flush the batch
    std::thread::sleep(Duration::from_millis(500));
    let stats = server.stats();
    server.drain();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let m = server.wait().unwrap();
    (results, m, stats)
}

#[test]
fn conn_drop_loses_only_its_own_response_and_batchmates_stay_bit_identical() {
    let _x = faults::exclusive();
    let _d = Disarm;
    let n = 6;
    let specs: Vec<String> = (0..n)
        .map(|i| {
            format!(
                r#"{{"module":"k_proj","layer":{},"rows":4,"seed":{}}}"#,
                i % 4,
                100 + i
            )
        })
        .collect();

    // fault-free baseline: every client completes
    faults::disarm();
    let (base, base_m, _) = batched_run(n, &specs);
    assert!(base.iter().all(Option::is_some), "baseline must be clean");
    assert_eq!(base_m.completed as usize, n);

    // same stream with a deterministic subset of connections torn down
    // after submit, before any response byte
    faults::arm("net.conn_drop=mod:3:1").unwrap();
    let (chaos, m, stats) = batched_run(n, &specs);
    faults::disarm();

    let dropped = chaos.iter().filter(|r| r.is_none()).count();
    assert_eq!(dropped, 2, "keys 1 and 4 of 0..6 are torn down");
    assert_eq!(stats.conn_dropped.load(Ordering::Relaxed), 2);
    // the jobs behind the dropped connections still execute — the core
    // owes every admitted job a terminal response, wire fate aside
    assert_eq!(m.completed as usize, n, "dropped conns do not lose jobs");
    assert_eq!(m.errors, 0, "a wire fault must not fail any job");
    assert_eq!(m.quarantined, 0, "a wire fault must not quarantine batchmates");
    for (i, (got, want)) in chaos.iter().zip(&base).enumerate() {
        if let Some(bits) = got {
            assert_eq!(
                bits,
                want.as_ref().unwrap(),
                "surviving client {i} diverged from the fault-free run"
            );
        }
    }
}

#[test]
fn overload_sheds_with_429_and_positive_retry_after() {
    let _x = faults::exclusive();
    let _d = Disarm;
    faults::disarm();
    let server = tiny_server(
        ServeConfig {
            workers: 1,
            max_batch: 4,
            queue_depth: 64,
            shed_queued: 2,
            paused: true,
            ..Default::default()
        },
        NetConfig::default(),
    );
    let addr = server.addr();

    // fill the admission bound with clients that block on their results
    let occupants: Vec<_> = (0..2)
        .map(|i| {
            let spec =
                format!(r#"{{"module":"k_proj","layer":{i},"rows":4,"seed":{}}}"#, 40 + i);
            std::thread::spawn(move || post(addr, "/analyze", spec.as_bytes()))
        })
        .collect();
    std::thread::sleep(Duration::from_millis(400));

    // over the bound: shed, not queue growth
    let resp = post(addr, "/analyze", br#"{"module":"k_proj","layer":3,"rows":4,"seed":50}"#);
    assert_eq!(resp.status, 429);
    assert_eq!(error_name(&resp), "shed");
    let retry_secs: u64 = resp.header("retry-after").unwrap().parse().unwrap();
    assert!(retry_secs >= 1, "whole-second Retry-After rounds up");
    let retry_us: u64 = resp.header("x-retry-after-micros").unwrap().parse().unwrap();
    assert!(retry_us >= 100, "live hint from the shed controller");

    // drain releases the occupants with full 200 results
    server.drain();
    for h in occupants {
        assert_eq!(h.join().unwrap().status, 200);
    }
    let stats = server.stats();
    assert_eq!(stats.status(429), 1);
    let m = server.wait().unwrap();
    assert_eq!(m.shed, 1);
    assert_eq!(m.completed, 2);
}

fn synth_plan(mode: Mode) -> QuantPlan {
    QuantPlan {
        provenance: Provenance::default(),
        entries: (0..4)
            .map(|layer| PlanEntry {
                module: "k_proj".into(),
                layer,
                bits: 4,
                c_in: 256,
                mode,
                alpha: 0.5,
                predicted_error: 1.0,
                difficulty_before: 2.0,
                difficulty_after: 1.0,
                smooth: None,
            })
            .collect(),
    }
}

#[test]
fn drain_racing_plan_hot_swap_drops_zero_in_flight_responses() {
    let _x = faults::exclusive();
    let _d = Disarm;
    faults::disarm();
    let dir = std::env::temp_dir().join("smoothrot_chaos_net_swap_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plan.json");
    synth_plan(Mode::Rotate).save(&path).unwrap();
    let reg = Arc::new(PlanRegistry::load(&path).unwrap());
    let gen0 = reg.generation();

    let (core, rx) = {
        let reg = Arc::clone(&reg);
        CoreServer::start_with_telemetry(
            ServeConfig { workers: 1, max_batch: 8, queue_depth: 64, paused: true, ..Default::default() },
            None,
            None,
            move |_| Ok(NativeBatchExecutor::with_plan_exec(Arc::clone(&reg), 1, ExecMode::F32)),
        )
    };
    let server =
        NetServer::start(NetConfig::default(), core, rx, None, synth_job_builder(STREAM_SEED))
            .unwrap();
    let addr = server.addr();

    // six in-flight clients, queued behind the paused scheduler
    let n = 6;
    let clients: Vec<_> = (0..n)
        .map(|i| {
            let spec =
                format!(r#"{{"module":"k_proj","layer":{},"rows":4,"seed":{}}}"#, i % 4, 200 + i);
            std::thread::spawn(move || post(addr, "/analyze", spec.as_bytes()))
        })
        .collect();
    std::thread::sleep(Duration::from_millis(500));

    // hot-swap the plan continuously while the drain runs
    let stop = Arc::new(AtomicBool::new(false));
    let swapper = {
        let reg = Arc::clone(&reg);
        let stop = Arc::clone(&stop);
        let path = path.clone();
        std::thread::spawn(move || {
            let mut flip = 0u32;
            while !stop.load(Ordering::SeqCst) {
                let mode = if flip % 2 == 0 { Mode::None } else { Mode::Rotate };
                synth_plan(mode).save(&path).unwrap();
                let _ = reg.reload_if_changed();
                flip += 1;
                std::thread::sleep(Duration::from_millis(5));
            }
        })
    };

    server.drain();
    // zero dropped in-flight responses: every client gets a full 200
    // with a complete result line, whatever plan generation served it
    for (i, h) in clients.into_iter().enumerate() {
        let resp = h.join().unwrap();
        assert_eq!(resp.status, 200, "in-flight client {i} lost its response");
        let text = String::from_utf8(resp.body).unwrap();
        let line = jsonio::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(line.get("status").and_then(Json::as_usize), Some(200));
        assert_eq!(
            line.get("errors_bits").and_then(Json::as_arr).map(<[Json]>::len),
            Some(4),
            "client {i} got a truncated result line"
        );
    }
    let stats = server.stats();
    let m = server.wait().unwrap();
    stop.store(true, Ordering::SeqCst);
    swapper.join().unwrap();

    assert_eq!(m.completed as usize, n);
    assert_eq!(m.errors, 0);
    assert_eq!(m.drains, 1);
    assert_eq!(stats.conn_dropped.load(Ordering::Relaxed), 0);
    assert_eq!(stats.partial_write.load(Ordering::Relaxed), 0);
    assert!(reg.generation() > gen0, "at least one hot-swap landed mid-drain");
    std::fs::remove_dir_all(&dir).ok();
}
