//! Chaos suite: the serving stack under deterministic fault injection
//! (check = proptest-lite, [`smoothrot::faults`] = failpoints).
//!
//! Over seeded fault schedules — executor panics, forced deadline
//! expiries, plan-reload corruption — crossed with runner topologies
//! and stealing modes, the stack must keep its contract: every
//! submitted job gets **exactly one** terminal response, no runner
//! dies permanently, the plan registry never serves a torn artifact
//! (generation moves monotonically, only on successful swaps), and
//! every *unfaulted* job's output is bit-identical to a fault-free
//! run.  The CLI tests at the bottom pin that operator-facing failures
//! (missing plan, unwritable metrics target, malformed fault spec) are
//! named errors with a nonzero exit, never a panic backtrace.
//!
//! Every test that arms the process-global fault plan holds
//! [`faults::exclusive`] for its whole body and disarms on drop, so
//! this suite is safe under cargo's parallel test runner.

use smoothrot::calib::plan::{PlanEntry, Provenance, QuantPlan};
use smoothrot::calib::registry::{PlanRegistry, RELOAD_BACKOFF_INITIAL};
use smoothrot::check::{check, ensure};
use smoothrot::coordinator::Job;
use smoothrot::faults;
use smoothrot::rng::Rng;
use smoothrot::serve::shard::{serve_all_sharded, ShardBy, ShardConfig};
use smoothrot::serve::{
    serve_all, NativeBatchExecutor, Response, ServeConfig, Server, SubmitError,
};
use smoothrot::telemetry::{self, Telemetry};
use smoothrot::tensor::Matrix;
use smoothrot::transforms::Mode;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Disarm the global fault plan when dropped — keeps a failed
/// assertion from leaking an armed plan into the next test.
struct Disarm;

impl Drop for Disarm {
    fn drop(&mut self) {
        faults::disarm();
    }
}

/// Deterministic request stream: real (seeded) activations and weights
/// so outputs are meaningful and bit-comparable across runs.
fn requests(n: usize, layers: usize, seed: u64) -> Vec<(usize, Job)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let rows = 2 + (i % 3);
            let x = Matrix::from_vec(rows, 8, rng.normals_f32(rows * 8));
            let w = Matrix::from_vec(8, 4, rng.normals_f32(32));
            let job = Job {
                id: i as u64,
                layer: i % layers,
                module: "k_proj",
                x,
                w,
                alpha: 0.5,
                bits: 4,
            };
            (i % 3, job)
        })
        .collect()
}

fn by_id(rs: &[Response]) -> BTreeMap<u64, &Response> {
    rs.iter().map(|r| (r.id, r)).collect()
}

#[test]
fn prop_panic_schedules_keep_exactly_once_and_bit_identity() {
    let _x = faults::exclusive();
    let _d = Disarm;
    check("chaos: panic schedule x topology -> exactly-once + bit identity", 9, |g| {
        let runners = *g.choose(&[1usize, 2, 4]);
        let stealing = g.usize_in(0, 1) == 1;
        let modulus = g.usize_in(2, 5) as u64;
        let residue = g.usize_in(0, modulus as usize - 1) as u64;
        let n = g.usize_in(8, 24);
        let seed = 7000 + g.usize_in(0, 999) as u64;
        let reqs = requests(n, 4, seed);
        let cfg = ShardConfig {
            runners,
            shard_by: ShardBy::Layer,
            stealing,
            base: ServeConfig { workers: 1, max_batch: 4, queue_depth: 64, ..Default::default() },
        };

        // fault-free baseline
        faults::disarm();
        let (base, base_m) =
            serve_all_sharded(cfg, reqs.clone(), |_| Ok(NativeBatchExecutor::with_threads(1)))
                .map_err(|e| e.to_string())?;
        ensure(base_m.errors == 0, "the fault-free baseline must be clean")?;

        // same stream under a seeded panic schedule: jobs with
        // id % modulus == residue panic on every dispatch
        faults::arm(&format!("serve.exec_panic=mod:{modulus}:{residue}"))?;
        let (chaos, m) = serve_all_sharded(cfg, reqs, |_| Ok(NativeBatchExecutor::with_threads(1)))
            .map_err(|e| e.to_string())?;
        faults::disarm();

        let poisoned = (0..n as u64).filter(|id| id % modulus == residue).count() as u64;
        ensure(chaos.len() == n, format!("lost responses: {} of {n}", chaos.len()))?;
        ensure(m.completed as usize == n, "metrics.completed mismatch")?;
        ensure(m.quarantined == poisoned, format!("quarantined {} != {poisoned}", m.quarantined))?;
        ensure(m.errors == poisoned, "only poisoned jobs may error")?;

        let base_by_id = by_id(&base);
        let mut seen = vec![false; n];
        for r in &chaos {
            let idx = r.id as usize;
            ensure(idx < n && !seen[idx], format!("job {idx} duplicated or unknown"))?;
            seen[idx] = true;
            if r.id % modulus == residue {
                let e = r.out.as_ref().err().ok_or("poisoned job did not error")?;
                ensure(e.contains("quarantined after panic"), format!("wrong error: {e}"))?;
            } else {
                let got = r.out.as_ref().map_err(|e| format!("unfaulted job {idx}: {e}"))?;
                let want = base_by_id[&r.id].out.as_ref().map_err(|e| e.clone())?;
                ensure(got == want, format!("job {idx} diverged from the fault-free run"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_forced_deadline_expiry_evicts_exactly_the_scheduled_subset() {
    let _x = faults::exclusive();
    let _d = Disarm;
    check("chaos: deadline schedule -> exact eviction set, exactly-once", 8, |g| {
        let modulus = g.usize_in(2, 4) as u64;
        let residue = g.usize_in(0, modulus as usize - 1) as u64;
        let n = g.usize_in(6, 18);
        let reqs = requests(n, 4, 8800 + g.usize_in(0, 99) as u64);
        // paused server: the whole stream is queued before the
        // close-triggered dispatch, so the eviction scan sees every job
        faults::arm(&format!("serve.deadline_expire=mod:{modulus}:{residue}"))?;
        let cfg = ServeConfig {
            workers: g.usize_in(1, 2),
            max_batch: 4,
            queue_depth: 64,
            paused: true,
            ..Default::default()
        };
        let (responses, m) = serve_all(cfg, reqs, |_| Ok(NativeBatchExecutor::with_threads(1)))
            .map_err(|e| e.to_string())?;
        faults::disarm();

        let forced = (0..n as u64).filter(|id| id % modulus == residue).count() as u64;
        ensure(responses.len() == n, "every job needs a terminal response")?;
        ensure(m.deadline_expired == forced, "eviction count mismatch")?;
        ensure(m.completed as usize == n, "evictions count as completions")?;
        let mut seen = vec![false; n];
        for r in &responses {
            let idx = r.id as usize;
            ensure(idx < n && !seen[idx], format!("job {idx} duplicated or unknown"))?;
            seen[idx] = true;
            if r.id % modulus == residue {
                let e = r.out.as_ref().err().ok_or("forced-expired job did not error")?;
                ensure(e.contains("deadline expired"), format!("wrong error: {e}"))?;
                ensure(r.worker == usize::MAX, "evicted jobs never reach a worker")?;
            } else {
                ensure(r.out.is_ok(), format!("unfaulted job {idx} must succeed"))?;
            }
        }
        Ok(())
    });
}

fn plan_with_mode(mode: Mode) -> QuantPlan {
    QuantPlan {
        provenance: Provenance::default(),
        entries: (0..4)
            .map(|layer| PlanEntry {
                module: "k_proj".into(),
                layer,
                bits: 4,
                c_in: 8,
                mode,
                alpha: 0.5,
                predicted_error: 1.0,
                difficulty_before: 2.0,
                difficulty_after: 1.0,
                smooth: None,
            })
            .collect(),
    }
}

#[test]
fn reload_corruption_keeps_the_old_plan_live_and_recovers_after_backoff() {
    let _x = faults::exclusive();
    let _d = Disarm;
    faults::disarm();
    let dir = std::env::temp_dir().join("smoothrot_chaos_reload_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plan.json");
    plan_with_mode(Mode::Rotate).save(&path).unwrap();
    let reg = Arc::new(PlanRegistry::load(&path).unwrap());
    let t = Telemetry::new();
    t.add_collector(telemetry::plan_registry_collector(&reg));
    let gen0 = reg.generation();
    let hash0 = reg.content_hash();

    // a genuinely torn artifact on disk: truncated JSON
    std::fs::write(&path, "{\"version\": 1, \"entries\": [").unwrap();
    assert!(reg.reload_if_changed().is_err(), "torn plan must fail the reload");
    assert_eq!(reg.content_hash(), hash0, "the old plan stays live");
    assert_eq!(reg.generation(), gen0, "generation only moves on successful swaps");
    assert_eq!(reg.reload_failed(), 1);
    // inside the backoff window the (still corrupt) file is not even read
    assert_eq!(reg.reload_if_changed(), Ok(false));
    assert_eq!(reg.reload_failed(), 1, "backoff window suppresses re-parsing");
    assert_eq!(
        t.snapshot().counter("smoothrot_reload_failed", &[]),
        Some(1),
        "reload_failed surfaces through the registry collector"
    );

    // a good rewrite that the failpoint forces to be treated as torn
    std::thread::sleep(RELOAD_BACKOFF_INITIAL + std::time::Duration::from_millis(50));
    plan_with_mode(Mode::None).save(&path).unwrap();
    faults::arm("plan.reload_corrupt=hit:1").unwrap();
    assert!(reg.reload_if_changed().is_err(), "failpoint-forced corruption");
    assert_eq!(reg.generation(), gen0);
    assert_eq!(reg.content_hash(), hash0);
    assert_eq!(reg.reload_failed(), 2);
    faults::disarm();

    // after the (doubled) backoff expires the same file loads cleanly
    std::thread::sleep(2 * RELOAD_BACKOFF_INITIAL + std::time::Duration::from_millis(100));
    assert_eq!(reg.reload_if_changed(), Ok(true), "recovery after disarm + backoff");
    assert!(reg.generation() > gen0, "successful swap bumps the generation");
    assert_ne!(reg.content_hash(), hash0, "the new content is live");
    assert_eq!(reg.reload_failed(), 2, "recovery adds no failures");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fault_shed_drain_counters_round_trip_through_json_and_prometheus() {
    let _x = faults::exclusive();
    let _d = Disarm;
    // one run exercising every new counter: a panic fault, a forced
    // deadline expiry, shedding under queue pressure and a drain
    faults::arm("serve.exec_panic=mod:5:1;serve.deadline_expire=mod:5:2").unwrap();
    let t = Telemetry::new();
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 4,
        queue_depth: 64,
        shed_queued: 6,
        paused: true,
        ..Default::default()
    };
    let (server, rx) = Server::start_with_telemetry(cfg, Some(Arc::clone(&t)), |_| {
        Ok(NativeBatchExecutor::with_threads(1))
    });
    let mut shed = 0u64;
    for (tenant, job) in requests(10, 4, 41) {
        match server.submit(tenant, job) {
            Ok(()) => {}
            Err(SubmitError::Shed { retry_after_micros, .. }) => {
                assert!(retry_after_micros >= 100);
                shed += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert_eq!(shed, 4, "jobs 6..10 shed at the queue-pressure bound");
    server.drain();
    let m = server.finish();
    drop(rx);
    assert_eq!(m.shed, 4);
    assert_eq!(m.quarantined, 1, "job 1 (of the six admitted) is poisoned");
    assert_eq!(m.deadline_expired, 1, "job 2 is forced to expire");
    assert_eq!(m.drains, 1);
    m.fill(&t);

    let snap = t.snapshot();
    for (name, want) in [
        ("smoothrot_jobs_quarantined", m.quarantined),
        ("smoothrot_deadline_expired", m.deadline_expired),
        ("smoothrot_shed_total", m.shed),
        ("smoothrot_drain_total", m.drains),
    ] {
        assert_eq!(snap.counter(name, &[]), Some(want), "{name} in the live snapshot");
    }
    // JSON round trip preserves the counters bit for bit
    let back = smoothrot::telemetry::export::Snapshot::parse(&snap.to_json_string()).unwrap();
    for name in [
        "smoothrot_jobs_quarantined",
        "smoothrot_deadline_expired",
        "smoothrot_shed_total",
        "smoothrot_drain_total",
    ] {
        assert_eq!(back.counter(name, &[]), snap.counter(name, &[]), "{name} via JSON");
    }
    // Prometheus exposition carries all four with the right values
    let samples = smoothrot::telemetry::export::parse_prometheus(&snap.to_prometheus()).unwrap();
    for (name, want) in [
        ("smoothrot_jobs_quarantined", m.quarantined),
        ("smoothrot_deadline_expired", m.deadline_expired),
        ("smoothrot_shed_total", m.shed),
        ("smoothrot_drain_total", m.drains),
    ] {
        let got = samples.iter().find(|s| s.name == name && s.labels.is_empty());
        assert_eq!(got.map(|s| s.value), Some(want as f64), "{name} via Prometheus");
    }
}

/// Run the CLI binary and return `(status_ok, stderr)`.
fn run_cli(args: &[&str], env: &[(&str, &str)]) -> (bool, String) {
    let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_smoothrot"));
    cmd.args(args);
    for (k, v) in env {
        cmd.env(k, v);
    }
    let out = cmd.output().expect("spawn smoothrot CLI");
    (out.status.success(), String::from_utf8_lossy(&out.stderr).into_owned())
}

#[test]
fn cli_failures_are_named_errors_not_panics() {
    // missing --plan file
    let (ok, err) =
        run_cli(&["serve", "--backend", "native", "--plan", "/nonexistent/plan.json"], &[]);
    assert!(!ok, "missing plan must exit nonzero");
    assert!(err.contains("error:"), "named error expected, got:\n{err}");
    assert!(!err.contains("panicked"), "must not panic:\n{err}");

    // metrics target under a nonexistent directory
    let (ok, err) =
        run_cli(&["serve", "--requests", "1", "--metrics-file", "/nonexistent/dir/m.json"], &[]);
    assert!(!ok);
    assert!(err.contains("parent directory"), "named error expected, got:\n{err}");
    assert!(!err.contains("panicked"), "must not panic:\n{err}");

    // metrics target that is a directory
    let dir = std::env::temp_dir().join("smoothrot_chaos_cli_dir");
    std::fs::create_dir_all(&dir).unwrap();
    let (ok, err) =
        run_cli(&["serve", "--requests", "1", "--metrics-file", dir.to_str().unwrap()], &[]);
    assert!(!ok);
    assert!(err.contains("is a directory"), "named error expected, got:\n{err}");
    std::fs::remove_dir_all(&dir).ok();

    // malformed --faults spec
    let (ok, err) =
        run_cli(&["serve", "--requests", "1", "--faults", "serve.exec_panic=bogus"], &[]);
    assert!(!ok);
    assert!(err.contains("error: --faults"), "named error expected, got:\n{err}");

    // malformed SMOOTHROT_FAULTS env spec
    let (ok, err) = run_cli(&["serve", "--requests", "1"], &[("SMOOTHROT_FAULTS", "=always")]);
    assert!(!ok);
    assert!(err.contains("error: SMOOTHROT_FAULTS"), "named error expected, got:\n{err}");
}
