//! Cross-kernel differential harness: every SIMD backend the host
//! detects is pinned **bit-identical** to the scalar reference — `==`
//! on every output, never a tolerance.
//!
//! Why exact pinning is even possible: the integer microkernel
//! accumulates `i8 × i8` products in `i32`, the igemm overflow guard
//! proves no partial sum can leave `i32`, and exact integer addition
//! is associative — so any lane layout produces the same bits.  On the
//! float side, the per-token abs-max is an order-free `max` fold and
//! IEEE division/rounding are exactly specified, so the quantize path
//! pins exactly too (the AVX2 kernel emulates `f32::round`'s
//! ties-away-from-zero on top of hardware round-to-even; see
//! `kernels/simd`).
//!
//! The silent-skip hazard is handled head-on: a host without AVX2/NEON
//! runs only the scalar arm of every test here, which would let a
//! mis-provisioned CI runner vacuously pass — so the x86_64 CI leg
//! sets `SMOOTHROT_REQUIRE_BACKEND=avx2`, and
//! `required_backend_must_be_detected` turns "backend unavailable"
//! into a hard failure.

use smoothrot::check::{check, ensure};
use smoothrot::kernels::fused::analyze_planned_int;
use smoothrot::kernels::igemm::{igemm, igemm_packed_into_with};
use smoothrot::kernels::simd::{self, KernelBackend};
use smoothrot::kernels::workspace::Workspace;
use smoothrot::qtensor::{PackedWeight, PlannedWeight, QMatrix, ScaleAxis};
use smoothrot::tensor::Matrix;
use smoothrot::transforms::{self, Mode, RotationCache};

/// SIMD backends this host can actually run (the scalar reference is
/// implicit — it is what everything is compared against).
fn simd_backends() -> Vec<KernelBackend> {
    [KernelBackend::Avx2, KernelBackend::Neon]
        .into_iter()
        .filter(|b| b.available())
        .collect()
}

/// The anti-vacuity gate: when `SMOOTHROT_REQUIRE_BACKEND` names a
/// backend, it must be detected — otherwise every differential test in
/// this file would silently degenerate to scalar-vs-scalar and a
/// mis-provisioned CI host would pass the whole suite without running
/// a single SIMD instruction.
#[test]
fn required_backend_must_be_detected() {
    match simd::required_backend() {
        Ok(None) => {}
        Ok(Some(required)) => {
            assert!(
                required.available(),
                "{}={} but this host only detects {:?} — the SIMD differential suite would \
                 vacuously pass",
                simd::ENV_REQUIRE,
                required.name(),
                KernelBackend::detect().name()
            );
            assert!(
                simd_backends().contains(&required),
                "required backend {} missing from the differential matrix",
                required.name()
            );
        }
        Err(e) => panic!("{e}"),
    }
}

#[test]
fn prop_packed_igemm_bit_identical_across_backends() {
    let backends = simd_backends();
    check("SIMD packed igemm == scalar packed igemm, bit for bit", 40, |g| {
        let m = g.usize_in(1, 16);
        let k = g.usize_in(1, 200);
        let n = g.usize_in(1, 48); // crosses tile boundaries incl. ragged tails
        let bits = *g.choose(&[4u32, 8]);
        let threads = *g.choose(&[1usize, 2, 3, 8]);
        let x = g.matrix(m, k);
        let w = g.matrix(k, n);
        // i4 activations at 4 bits exercise the nibble-unpack path in
        // front of the SIMD tile loop
        let qx = QMatrix::quantize(&x, bits, ScaleAxis::PerRow)?;
        let pw = PackedWeight::pack(&QMatrix::quantize(&w, bits, ScaleAxis::PerCol)?)?;
        let mut ws = Workspace::new();
        let mut want = vec![0.0f32; m * n];
        igemm_packed_into_with(&mut want, &qx, &pw, &mut ws, threads, KernelBackend::Scalar)?;
        for &be in &backends {
            let mut got = vec![f32::NAN; m * n];
            igemm_packed_into_with(&mut got, &qx, &pw, &mut ws, threads, be)?;
            ensure(
                got == want,
                format!("{be}: m={m} k={k} n={n} bits={bits} threads={threads} diverged"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn adversarial_igemm_edges_are_bit_identical() {
    // worst-case magnitudes at the overflow-guard boundary: all-qmax
    // activation codes against a weight whose even lanes accumulate to
    // within 4103 of i32::MAX and whose odd lanes alternate sign
    let qm = 127u64;
    let k_max = (i32::MAX as u64 / (qm * qm)) as usize; // 133_144
    let c = 3.0f32;
    let x = Matrix::from_vec(1, k_max, vec![c; k_max]);
    let wdata: Vec<f32> = (0..k_max * 16)
        .map(|i| {
            let (kk, j) = (i / 16, i % 16);
            if j % 2 == 0 {
                c // constant lane: partial sums climb monotonically
            } else if kk % 2 == 0 {
                c // alternating lane: cancels every other step
            } else {
                -c
            }
        })
        .collect();
    let w = Matrix::from_vec(k_max, 16, wdata);
    let qx = QMatrix::quantize_i8(&x, 8, ScaleAxis::PerRow).unwrap();
    assert!(
        qx.i8_codes().unwrap().iter().all(|&v| v == 127),
        "fixture must hit the qmax code on every element"
    );
    let qw = QMatrix::quantize_i8(&w, 8, ScaleAxis::PerCol).unwrap();
    assert!(qw.i8_codes().unwrap().iter().all(|&v| v.unsigned_abs() as u64 == qm));
    let pw = PackedWeight::pack(&qw).unwrap();

    let mut ws = Workspace::new();
    // independent third computation: the row-major integer kernel
    let reference = igemm(&qx, &qw, &mut ws, 1).unwrap();
    let mut want = vec![0.0f32; 16];
    igemm_packed_into_with(&mut want, &qx, &pw, &mut ws, 1, KernelBackend::Scalar).unwrap();
    assert_eq!(want.as_slice(), reference.as_slice(), "scalar packed vs row-major");
    for be in simd_backends() {
        let mut got = vec![f32::NAN; 16];
        igemm_packed_into_with(&mut got, &qx, &pw, &mut ws, 1, be).unwrap();
        assert_eq!(got, want, "{be} at k = overflow-guard boundary ({k_max})");
    }

    // one past the guard: every backend must reject identically, not
    // silently wrap
    let x_over = Matrix::from_vec(1, k_max + 1, vec![c; k_max + 1]);
    let w_over = Matrix::from_vec(k_max + 1, 16, vec![c; (k_max + 1) * 16]);
    let qx_over = QMatrix::quantize_i8(&x_over, 8, ScaleAxis::PerRow).unwrap();
    let pw_over =
        PackedWeight::pack(&QMatrix::quantize_i8(&w_over, 8, ScaleAxis::PerCol).unwrap()).unwrap();
    let mut out = vec![0.0f32; 16];
    let scalar_err =
        igemm_packed_into_with(&mut out, &qx_over, &pw_over, &mut ws, 1, KernelBackend::Scalar)
            .unwrap_err();
    assert!(scalar_err.contains("overflow"), "{scalar_err}");
    for be in simd_backends() {
        let err = igemm_packed_into_with(&mut out, &qx_over, &pw_over, &mut ws, 1, be).unwrap_err();
        assert_eq!(err, scalar_err, "{be}: guard must fire identically");
    }
}

#[test]
fn prop_quantize_and_grid_bit_identical_across_backends() {
    let backends = simd_backends();
    check("per-token quantize + grid identical under every backend", 40, |g| {
        let rows = g.usize_in(1, 12);
        let cols = g.usize_in(1, 70); // crosses vector widths + tails
        let bits = *g.choose(&[2u32, 4, 8]);
        let x = g.matrix(rows, cols);
        for axis in [ScaleAxis::PerRow, ScaleAxis::PerCol] {
            let want = simd::with_backend(KernelBackend::Scalar, || {
                QMatrix::quantize_i8(&x, bits, axis)
            })?;
            for &be in &backends {
                let got = simd::with_backend(be, || QMatrix::quantize_i8(&x, bits, axis))?;
                ensure(
                    got.scales() == want.scales(),
                    format!("{be}: bits={bits} {axis:?} grid steps diverged"),
                )?;
                ensure(
                    got.i8_codes() == want.i8_codes(),
                    format!("{be}: bits={bits} {axis:?} codes diverged"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn adversarial_quantize_ties_are_bit_identical() {
    // exact grid ties are the one place x86 vector rounding
    // (ties-to-even) disagrees with f32::round (ties-away-from-zero);
    // delta = 1 makes v / delta exact so the ties genuinely fire, and
    // the vector is longer than any SIMD width to cover lanes + tail
    let mut row: Vec<f32> = Vec::new();
    for q in [-4.0f32, -3.0, -2.0, -1.0, 0.0, 1.0, 2.0, 3.0] {
        row.push(q + 0.5);
        row.push(q - 0.5);
        row.push(q + 0.49999997); // just below a tie: must NOT step out
        row.push(q);
    }
    row.extend([126.5, 127.5, -126.5, -127.5, 1e30, -1e30, -0.0]);
    for delta in [1.0f32, 0.5, 0.25] {
        let mut want = vec![0i8; row.len()];
        simd::quantize_row(KernelBackend::Scalar, &row, delta, 127.0, &mut want);
        for be in simd_backends() {
            let mut got = vec![0i8; row.len()];
            simd::quantize_row(be, &row, delta, 127.0, &mut got);
            assert_eq!(got, want, "{be} delta={delta}");
        }
    }
}

#[test]
fn prop_planned_int_errors_bit_identical_across_backends() {
    let backends = simd_backends();
    if backends.is_empty() {
        // nothing to compare; required_backend_must_be_detected keeps
        // this from masking a mis-provisioned CI host
        return;
    }
    check("planned-int Eq.2 errors identical under every backend", 10, |g| {
        let rows = g.usize_in(2, 16);
        let c_in = *g.choose(&[8usize, 16, 32]);
        let c_out = g.usize_in(2, 10);
        let bits = *g.choose(&[4u32, 8]);
        let threads = g.usize_in(1, 3);
        let alpha = g.f32_in(0.2, 0.8);
        let x = g.matrix(rows, c_in);
        let w = g.matrix(c_in, c_out);
        let s = transforms::smooth_scales(&x, &w, alpha);
        let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
        let mut cache = RotationCache::new();
        for mode in Mode::ALL {
            let smooth =
                matches!(mode, Mode::Smooth | Mode::SmoothRotate).then_some((&s[..], &inv[..]));
            let rot = if matches!(mode, Mode::Rotate | Mode::SmoothRotate) {
                Some(cache.get(c_in)?.clone())
            } else {
                None
            };
            let pw = PlannedWeight::from_plan(&w, smooth.map(|(s, _)| s), rot.as_ref(), bits, 1)?;
            let mut ws = Workspace::new();
            let want = simd::with_backend(KernelBackend::Scalar, || {
                analyze_planned_int(&x, &w, bits, mode, smooth, rot.as_ref(), &pw, &mut ws, threads)
            })?;
            for &be in &backends {
                let got = simd::with_backend(be, || {
                    analyze_planned_int(
                        &x,
                        &w,
                        bits,
                        mode,
                        smooth,
                        rot.as_ref(),
                        &pw,
                        &mut ws,
                        threads,
                    )
                })?;
                ensure(
                    got.errors == want.errors,
                    format!("{be} {mode:?}: Eq.2 errors diverged ({:?} vs {:?})",
                        got.errors, want.errors),
                )?;
                ensure(
                    got.act_difficulty == want.act_difficulty,
                    format!("{be} {mode:?}: act_difficulty diverged"),
                )?;
                ensure(
                    got.act_absmax == want.act_absmax,
                    format!("{be} {mode:?}: act_absmax diverged"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_trim_between_batches_is_invisible_to_packed_simd_igemm() {
    // Workspace::trim drops pooled scratch; PackedWeight panels are
    // owned by the weight, not the workspace, so a trim between
    // batches must never perturb a packed GEMM — under any backend
    let mut all = vec![KernelBackend::Scalar];
    all.extend(simd_backends());
    check("trim between batches never invalidates a packed panel", 15, |g| {
        let m = g.usize_in(1, 10);
        let k = *g.choose(&[16usize, 33, 64]);
        let n = g.usize_in(1, 40);
        let x = g.matrix(m, k);
        let w = g.matrix(k, n);
        // i4 activations force the unpack scratch that trim reclaims
        let qx = QMatrix::quantize(&x, 4, ScaleAxis::PerRow)?;
        let pw = PackedWeight::pack(&QMatrix::quantize(&w, 4, ScaleAxis::PerCol)?)?;
        for &be in &all {
            let mut ws = Workspace::new();
            let mut want = vec![0.0f32; m * n];
            igemm_packed_into_with(&mut want, &qx, &pw, &mut ws, 2, be)?;
            ws.trim(0); // drop every pooled buffer between batches
            let mut got = vec![f32::NAN; m * n];
            igemm_packed_into_with(&mut got, &qx, &pw, &mut ws, 2, be)?;
            ensure(got == want, format!("{be}: trim(0) between batches changed the output"))?;
            ensure(ws.pooled_bytes() > 0, "second run must have repooled its scratch")?;
        }
        Ok(())
    });
}

#[test]
fn steady_state_packed_simd_igemm_is_allocation_free_with_trim() {
    // the serving pattern: warm workspace, generous trim budget between
    // batches — the SIMD path must stay allocation-free like scalar
    let mut rng = smoothrot::rng::Rng::new(55);
    let x = Matrix::from_vec(6, 32, rng.normals_f32(6 * 32));
    let w = Matrix::from_vec(32, 24, rng.normals_f32(32 * 24));
    let qx = QMatrix::quantize(&x, 4, ScaleAxis::PerRow).unwrap();
    let pw = PackedWeight::pack(&QMatrix::quantize(&w, 4, ScaleAxis::PerCol).unwrap()).unwrap();
    let mut backends = vec![KernelBackend::Scalar];
    backends.extend(simd_backends());
    for be in backends {
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; 6 * 24];
        igemm_packed_into_with(&mut out, &qx, &pw, &mut ws, 1, be).unwrap();
        let (_, warm) = ws.stats();
        for _ in 0..5 {
            ws.trim(16 << 20); // the executor's between-batches budget
            igemm_packed_into_with(&mut out, &qx, &pw, &mut ws, 1, be).unwrap();
        }
        let (_, allocs) = ws.stats();
        assert_eq!(allocs, warm, "{be}: steady-state SIMD igemm must not allocate");
    }
}
