//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; they are skipped (with a
//! loud message) when the artifacts directory is absent so plain
//! `cargo test` stays green in a fresh checkout.

use smoothrot::coordinator::NativeExecutor;
use smoothrot::pipeline::{self};
use smoothrot::runtime::Runtime;
use smoothrot::tensor::Matrix;
use smoothrot::transforms::{self, Mode};

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("SMOOTHROT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn rand_xw(c_in: usize, c_out: usize, seed: u64) -> (Matrix, Matrix) {
    let mut rng = smoothrot::rng::Rng::new(seed);
    (
        Matrix::from_vec(128, c_in, rng.normals_f32(128 * c_in)),
        Matrix::from_vec(c_in, c_out, rng.normals_f32(c_in * c_out)),
    )
}

#[test]
fn manifest_loads_and_validates() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let m = rt.manifest();
    assert_eq!(m.config.n_layers, 32);
    assert_eq!(m.modes, smoothrot::MODES);
    assert_eq!(m.artifacts.len(), 15);
    assert!(m.artifacts.contains_key("capture"));
    assert!(m.artifacts.contains_key("analyze_704x256"));
}

#[test]
fn qdq_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let (x, _) = rand_xw(256, 256, 1);
    let got = rt.qdq_token(&x).expect("qdq artifact");
    let want = smoothrot::quant::qdq(&x, 4, smoothrot::quant::Granularity::PerToken);
    for (a, b) in got.as_slice().iter().zip(want.as_slice()) {
        assert!((a - b).abs() < 1e-4, "pjrt {a} vs native {b}");
    }
}

#[test]
fn transform_artifacts_match_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    for (c_in, c_out) in [(256usize, 256usize), (256, 704), (704, 256)] {
        let (x, w) = rand_xw(c_in, c_out, 42 + c_in as u64);
        for mode in [Mode::Smooth, Mode::Rotate, Mode::SmoothRotate] {
            let (xh_p, wh_p) = rt.transform(mode, &x, &w).expect("pjrt transform");
            let (xh_n, wh_n) = transforms::apply(mode, &x, &w, 0.5).expect("native transform");
            let xs = xh_n.abs_max().max(1e-6);
            for (a, b) in xh_p.as_slice().iter().zip(xh_n.as_slice()) {
                assert!((a - b).abs() / xs < 1e-3, "{mode:?} {c_in}x{c_out} X: {a} vs {b}");
            }
            let ws = wh_n.abs_max().max(1e-6);
            for (a, b) in wh_p.as_slice().iter().zip(wh_n.as_slice()) {
                assert!((a - b).abs() / ws < 1e-3, "{mode:?} {c_in}x{c_out} W: {a} vs {b}");
            }
        }
    }
}

#[test]
fn analyze_artifact_matches_native_mirror() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let (x, w) = rand_xw(256, 256, 7);
    let pjrt = rt.analyze(&x, &w).expect("pjrt analyze");
    let native = NativeExecutor::analyze(&x, &w, 4, 0.5).expect("native analyze");
    for i in 0..4 {
        let rel = (pjrt.errors[i] - native.errors[i]).abs() / native.errors[i].max(1e-9);
        assert!(rel < 5e-2, "mode {i} error: pjrt {} vs native {}", pjrt.errors[i], native.errors[i]);
        let rel = (pjrt.act_difficulty[i] - native.act_difficulty[i]).abs()
            / native.act_difficulty[i].max(1e-9);
        assert!(rel < 1e-2, "mode {i} act_difficulty mismatch");
    }
}

#[test]
fn capture_matches_golden_checksums() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let cap = rt.capture().expect("capture");
    let golden = smoothrot::jsonio::parse(
        &std::fs::read_to_string(format!("{dir}/golden.json")).expect("golden.json"),
    )
    .expect("parse golden");
    let sums = golden.get("capture_checksums").expect("capture_checksums");
    for (module, stack) in [
        ("k_proj", &cap.attn_in),
        ("o_proj", &cap.o_in),
        ("gate_proj", &cap.ffn_in),
        ("down_proj", &cap.down_in),
    ] {
        let g = sums.get(module).unwrap_or_else(|| panic!("golden missing {module}"));
        let want_sum = g.get("sum").and_then(|j| j.as_f64()).unwrap();
        let want_abs_sum = g.get("abs_sum").and_then(|j| j.as_f64()).unwrap();
        let want_max = g.get("abs_max").and_then(|j| j.as_f64()).unwrap();
        let got_sum: f64 = stack.as_slice().iter().map(|&v| v as f64).sum();
        let got_abs_sum: f64 = stack.as_slice().iter().map(|&v| (v as f64).abs()).sum();
        let got_max = stack
            .as_slice()
            .iter()
            .fold(0.0f64, |m, &v| m.max((v as f64).abs()));
        // the net sum is cancellation-dominated (it is ~1e-2 of the
        // absolute mass), so its drift is judged relative to abs_sum;
        // abs_sum and abs_max drift with the cross-XLA-version noise
        assert!(
            (got_sum - want_sum).abs() / want_abs_sum < 1e-3,
            "{module} sum: got {got_sum} want {want_sum} (abs mass {want_abs_sum})"
        );
        assert!(
            (got_abs_sum - want_abs_sum).abs() / want_abs_sum < 5e-3,
            "{module} abs_sum: got {got_abs_sum} want {want_abs_sum}"
        );
        assert!(
            (got_max - want_max).abs() / want_max.max(1.0) < 1e-2,
            "{module} abs_max: got {got_max} want {want_max}"
        );
    }
}

#[test]
fn analyze_matches_golden_cases() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let workload = pipeline::load_workload(&rt).expect("workload");
    let golden = smoothrot::jsonio::parse(
        &std::fs::read_to_string(format!("{dir}/golden.json")).expect("golden.json"),
    )
    .expect("parse golden");
    let cases = golden.get("analyze").and_then(|j| j.as_arr()).expect("analyze cases");
    assert!(!cases.is_empty());
    for case in cases {
        let module: &'static str = smoothrot::MODULES
            .into_iter()
            .find(|m| Some(*m) == case.get("module").and_then(|j| j.as_str()))
            .expect("module");
        let layer = case.get("layer").and_then(|j| j.as_usize()).unwrap();
        let want = case.get("errors").and_then(|j| j.as_f64_vec()).unwrap();
        let (x, w) = workload.pair(&rt, module, layer);
        let got = rt.analyze(&x, &w).expect("analyze");
        for (i, (&w_e, g_e)) in want.iter().zip(got.errors).enumerate() {
            // golden was produced by jaxlib's XLA, the runtime is
            // xla_extension 0.5.1 — fusion differences flip a few RTN
            // roundings, so Eq. 2 errors agree to ~1e-2, not 1e-6
            let rel = (w_e - g_e).abs() / w_e.abs().max(1e-9);
            assert!(rel < 5e-2, "{module} L{layer} mode {i}: golden {w_e} vs pjrt {g_e} ({rel:.2e})");
        }
    }
}

#[test]
fn paper_claims_on_massive_layers() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let cfg = rt.manifest().config.clone();
    let workload = pipeline::load_workload(&rt).expect("workload");
    for &l in &cfg.massive_layers {
        let (x, w) = workload.pair(&rt, "down_proj", l);
        let out = rt.analyze(&x, &w).expect("analyze");
        // Sec. IV-D: rotation underperforms even the untransformed model
        assert!(
            out.errors[Mode::Rotate.index()] > out.errors[Mode::None.index()],
            "layer {l}: rotate {} <= none {}",
            out.errors[Mode::Rotate.index()],
            out.errors[Mode::None.index()]
        );
        // Sec. IV-E: smooth-rotation is the best of all four
        for m in [Mode::None, Mode::Smooth, Mode::Rotate] {
            assert!(
                out.errors[Mode::SmoothRotate.index()] < out.errors[m.index()],
                "layer {l}: smooth_rotate not best vs {m:?}"
            );
        }
    }
}
