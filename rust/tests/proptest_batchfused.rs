//! Property tests over the batch-fused integer hot path (check =
//! proptest-lite).
//!
//! The tentpole claim of the stacked execution path is **bit
//! identity**, not closeness: every step between a coalesced batch and
//! its per-job results — Eq. 4 column scaling, the Eq. 3/5 rotation,
//! Eq. 1 per-token grids, the integer GEMM rows, the Eq. 2 error fold —
//! is row-local, so stacking activation rows must change *nothing*.
//! These tests pin that across job counts, row counts, bit widths,
//! transform modes, thread counts and **kernel backends** (scalar plus
//! whatever SIMD the host detects), and pin the packed-tile GEMM
//! against the row-major kernel exactly (integer accumulation is
//! associative, so equality is `==`, never a tolerance).

use smoothrot::check::{check, ensure};
use smoothrot::kernels::fused::{analyze_planned_int, analyze_planned_int_batch};
use smoothrot::kernels::igemm::{igemm, igemm_packed_into_with};
use smoothrot::kernels::par::{self, ThreadPool};
use smoothrot::kernels::simd::{self, KernelBackend};
use smoothrot::kernels::workspace::Workspace;
use smoothrot::qtensor::{PackedWeight, PlannedWeight, QMatrix, ScaleAxis};
use smoothrot::tensor::Matrix;
use smoothrot::transforms::{self, Mode, RotationCache};
use std::sync::Arc;

/// Scalar plus every SIMD backend this host detects.
fn kernel_backends() -> Vec<KernelBackend> {
    let mut v = vec![KernelBackend::Scalar];
    v.extend([KernelBackend::Avx2, KernelBackend::Neon].into_iter().filter(|b| b.available()));
    v
}

#[test]
fn prop_batch_fused_bit_identical_to_per_job() {
    check("analyze_planned_int_batch == per-job analyze_planned_int, bit for bit", 15, |g| {
        let jobs_n = g.usize_in(1, 6);
        let c_in = *g.choose(&[8usize, 16, 32, 64]);
        let c_out = g.usize_in(2, 12);
        let bits = *g.choose(&[4u32, 8]);
        let threads = g.usize_in(1, 4);
        let alpha = g.f32_in(0.2, 0.8);
        let w = g.matrix(c_in, c_out);
        let rows: Vec<usize> = (0..jobs_n).map(|_| g.usize_in(1, 16)).collect();
        let xs: Vec<Matrix> = rows.iter().map(|&r| g.matrix(r, c_in)).collect();
        let s = transforms::smooth_scales(&xs[0], &w, alpha);
        let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
        let mut cache = RotationCache::new();
        for mode in Mode::ALL {
            let smooth =
                matches!(mode, Mode::Smooth | Mode::SmoothRotate).then_some((&s[..], &inv[..]));
            let rot = if matches!(mode, Mode::Rotate | Mode::SmoothRotate) {
                Some(cache.get(c_in)?.clone())
            } else {
                None
            };
            let pw = PlannedWeight::from_plan(&w, smooth.map(|(s, _)| s), rot.as_ref(), bits, 1)?;
            let mut ws_a = Workspace::new();
            let per_job: Vec<_> = xs
                .iter()
                .map(|x| {
                    analyze_planned_int(
                        x,
                        &w,
                        bits,
                        mode,
                        smooth,
                        rot.as_ref(),
                        &pw,
                        &mut ws_a,
                        threads,
                    )
                })
                .collect::<Result<_, _>>()?;
            let pairs: Vec<(&Matrix, &Matrix)> = xs.iter().map(|x| (x, &w)).collect();
            let mut ws_b = Workspace::new();
            let fused = analyze_planned_int_batch(
                &pairs,
                bits,
                mode,
                smooth,
                rot.as_ref(),
                &pw,
                &mut ws_b,
                threads,
            )?;
            ensure(fused.len() == per_job.len(), "result count mismatch")?;
            for (i, (a, b)) in per_job.iter().zip(&fused).enumerate() {
                ensure(
                    a.errors == b.errors,
                    format!("{mode:?} job {i}: errors diverged ({:?} vs {:?})", a.errors, b.errors),
                )?;
                ensure(
                    a.act_difficulty == b.act_difficulty,
                    format!("{mode:?} job {i}: act_difficulty diverged"),
                )?;
                ensure(
                    a.w_difficulty == b.w_difficulty,
                    format!("{mode:?} job {i}: w_difficulty diverged"),
                )?;
                ensure(
                    a.act_absmax == b.act_absmax,
                    format!("{mode:?} job {i}: act_absmax diverged"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batch_fused_thread_count_and_pool_invariant() {
    check("batch-fused results identical at every thread count and backend", 10, |g| {
        let jobs_n = g.usize_in(2, 5);
        let c_in = *g.choose(&[16usize, 32]);
        let c_out = g.usize_in(2, 8);
        let bits = *g.choose(&[4u32, 8]);
        let w = g.matrix(c_in, c_out);
        let rows: Vec<usize> = (0..jobs_n).map(|_| g.usize_in(1, 12)).collect();
        let xs: Vec<Matrix> = rows.iter().map(|&r| g.matrix(r, c_in)).collect();
        let mut cache = RotationCache::new();
        let rot = cache.get(c_in)?.clone();
        let pw = PlannedWeight::from_plan(&w, None, Some(&rot), bits, 1)?;
        let pairs: Vec<(&Matrix, &Matrix)> = xs.iter().map(|x| (x, &w)).collect();
        let mut ws = Workspace::new();
        // the anchor: serial, scalar kernels — every (threads, pool,
        // kernel backend) combination must reproduce it bit for bit
        let serial = simd::with_backend(KernelBackend::Scalar, || {
            analyze_planned_int_batch(&pairs, bits, Mode::Rotate, None, Some(&rot), &pw, &mut ws, 1)
        })?;
        for be in kernel_backends() {
            for threads in [2usize, 3, 8] {
                // scoped-thread backend
                let scoped = simd::with_backend(be, || {
                    analyze_planned_int_batch(
                        &pairs,
                        bits,
                        Mode::Rotate,
                        None,
                        Some(&rot),
                        &pw,
                        &mut ws,
                        threads,
                    )
                })?;
                // persistent-pool backend (what a serving executor
                // installs, with its kernel backend pinned around it)
                let pool = Arc::new(ThreadPool::new(threads));
                let pooled = simd::with_backend(be, || {
                    par::with_pool(Some(pool), || {
                        analyze_planned_int_batch(
                            &pairs,
                            bits,
                            Mode::Rotate,
                            None,
                            Some(&rot),
                            &pw,
                            &mut ws,
                            threads,
                        )
                    })
                })?;
                for ((a, b), c) in serial.iter().zip(&scoped).zip(&pooled) {
                    ensure(
                        a.errors == b.errors && a.errors == c.errors,
                        format!("{be} threads={threads}: errors diverged across backends"),
                    )?;
                    ensure(
                        a.act_difficulty == b.act_difficulty
                            && a.act_difficulty == c.act_difficulty,
                        format!("{be} threads={threads}: difficulty diverged across backends"),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_packed_igemm_equals_row_major_exactly() {
    check("igemm over PackedWeight == row-major igemm, exactly", 30, |g| {
        let m = g.usize_in(1, 24);
        let k = g.usize_in(1, 96);
        let n = g.usize_in(1, 40);
        let bits = *g.choose(&[4u32, 8]);
        let threads = g.usize_in(1, 4);
        let x = g.matrix(m, k);
        let w = g.matrix(k, n);
        // i4 activations at 4 bits exercise the nibble-unpack path;
        // the weight is packed from both storage kinds
        let qx = QMatrix::quantize(&x, bits, ScaleAxis::PerRow)?;
        let qw_i8 = QMatrix::quantize_i8(&w, bits, ScaleAxis::PerCol)?;
        let qw_at_rest = QMatrix::quantize(&w, bits, ScaleAxis::PerCol)?;
        let mut ws = Workspace::new();
        let want = igemm(&qx, &qw_i8, &mut ws, 1)?;
        for qw in [&qw_i8, &qw_at_rest] {
            let pw = PackedWeight::pack(qw)?;
            for be in kernel_backends() {
                let mut got = vec![0.0f32; m * n];
                igemm_packed_into_with(&mut got, &qx, &pw, &mut ws, threads, be)?;
                ensure(
                    got.as_slice() == want.as_slice(),
                    format!(
                        "be={be} m={m} k={k} n={n} bits={bits} threads={threads} \
                         packed_src={}: diverged",
                        if qw.is_packed() { "i4" } else { "i8" }
                    ),
                )?;
            }
        }
        Ok(())
    });
}
