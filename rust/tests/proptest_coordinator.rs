//! Property tests over the coordinator invariants (check = proptest-lite).

use smoothrot::check::{check, ensure, Gen};
use smoothrot::coordinator::{run_jobs, Executor, Job, JobResult, PoolConfig};
use smoothrot::runtime::AnalyzeOut;
use smoothrot::tensor::Matrix;

/// Executor that records what it sees and optionally sleeps.
struct ProbeExec {
    sleep_us: u64,
}

impl Executor for ProbeExec {
    fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
        if self.sleep_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.sleep_us));
        }
        // encode job identity into the output so results can be verified
        let mut out = AnalyzeOut::default();
        out.errors[0] = job.id as f64;
        out.errors[1] = job.layer as f64;
        Ok(out)
    }
}

fn make_jobs(g: &mut Gen, n: usize) -> Vec<Job> {
    (0..n)
        .map(|i| Job {
            id: i as u64,
            layer: g.usize_in(0, 7),
            module: *g.choose(&smoothrot::MODULES),
            x: Matrix::zeros(2, 2),
            w: Matrix::zeros(2, 2),
            alpha: 0.5,
            bits: 4,
        })
        .collect()
}

fn verify(results: &[JobResult], jobs_snapshot: &[(u64, usize)]) -> Result<(), String> {
    ensure(results.len() == jobs_snapshot.len(), "result count mismatch")?;
    // exactly once, correctly keyed
    let mut seen = vec![false; jobs_snapshot.len()];
    for r in results {
        let idx = r.id as usize;
        ensure(!seen[idx], format!("job {idx} completed twice"))?;
        seen[idx] = true;
        ensure(r.out.errors[0] as u64 == r.id, "result not keyed to its job")?;
        ensure(r.out.errors[1] as usize == jobs_snapshot[idx].1, "layer mismatch in result")?;
    }
    ensure(seen.iter().all(|&s| s), "some job never completed")
}

#[test]
fn prop_every_job_completes_exactly_once() {
    check("exactly-once completion over worker/queue configs", 25, |g| {
        let n = g.usize_in(1, 60);
        let jobs = make_jobs(g, n);
        let snapshot: Vec<(u64, usize)> = jobs.iter().map(|j| (j.id, j.layer)).collect();
        let cfg = PoolConfig { workers: g.usize_in(1, 6), queue_cap: g.usize_in(1, 8), threads: 1 };
        let (results, metrics) = run_jobs(jobs, cfg, |_| Ok(ProbeExec { sleep_us: 0 }))?;
        verify(&results, &snapshot)?;
        ensure(metrics.jobs == n, "metrics.jobs mismatch")?;
        ensure(
            metrics.per_worker_jobs.iter().sum::<usize>() == n,
            "per-worker counts don't sum to total",
        )
    });
}

#[test]
fn prop_queue_depth_never_exceeds_cap() {
    check("bounded queue respects its capacity", 10, |g| {
        let n = g.usize_in(10, 40);
        let jobs = make_jobs(g, n);
        let workers = g.usize_in(1, 4);
        let cap = g.usize_in(1, 6);
        let cfg = PoolConfig { workers, queue_cap: cap, threads: 1 };
        let (_, metrics) = run_jobs(jobs, cfg, move |_| Ok(ProbeExec { sleep_us: 200 }))?;
        // the depth counter includes jobs a worker has popped but not yet
        // decremented, so allow cap + workers + 1 slack
        ensure(
            metrics.max_queue_depth <= cap + workers + 1,
            format!("depth {} > cap {cap} + workers {workers}", metrics.max_queue_depth),
        )
    });
}

#[test]
fn prop_results_sorted_by_id() {
    check("results are returned in id order", 15, |g| {
        let n = g.usize_in(2, 50);
        let jobs = make_jobs(g, n);
        let cfg = PoolConfig { workers: g.usize_in(2, 5), queue_cap: 4, threads: 1 };
        let (results, _) = run_jobs(jobs, cfg, |_| Ok(ProbeExec { sleep_us: 50 }))?;
        for pair in results.windows(2) {
            ensure(pair[0].id < pair[1].id, "ids out of order")?;
        }
        Ok(())
    });
}

#[test]
fn prop_failures_always_reported() {
    struct SometimesFail {
        fail_id: u64,
    }
    impl Executor for SometimesFail {
        fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
            if job.id == self.fail_id {
                Err(format!("injected failure on {}", job.id))
            } else {
                Ok(AnalyzeOut::default())
            }
        }
    }
    check("a failing job fails the run", 15, |g| {
        let n = g.usize_in(3, 30);
        let fail_id = g.usize_in(0, n - 1) as u64;
        let jobs = make_jobs(g, n);
        let cfg = PoolConfig { workers: g.usize_in(1, 4), queue_cap: 4, threads: 1 };
        let res = run_jobs(jobs, cfg, move |_| Ok(SometimesFail { fail_id }));
        ensure(res.is_err(), "run must fail when a job fails")?;
        ensure(
            res.unwrap_err().contains("injected failure"),
            "error message must carry the executor's failure",
        )
    });
}
