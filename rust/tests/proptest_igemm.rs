//! Property tests over the integer execution path (check =
//! proptest-lite): integer GEMM vs the f32 `qdq`-then-`matmul`
//! reference, dequantize-vs-qdq bit identity, i4 pack/unpack identity,
//! thread-count invariance, and the planned integer eval tracking the
//! simulated planned eval.  Backend-sensitive properties run under
//! every kernel backend the host detects (scalar plus AVX2/NEON), so
//! the SIMD quantize and tile kernels are held to the same references
//! as the scalar code — see `tests/differential_kernels.rs` for the
//! dedicated scalar-vs-SIMD equality matrix.

use smoothrot::check::{check, close, ensure};
use smoothrot::kernels::fused::{analyze_planned, analyze_planned_int};
use smoothrot::kernels::igemm::igemm;
use smoothrot::kernels::simd::{self, KernelBackend};
use smoothrot::kernels::workspace::Workspace;
use smoothrot::qtensor::{pack_i4, unpack_i4, PlannedWeight, QMatrix, ScaleAxis};
use smoothrot::quant::{self, Granularity};
use smoothrot::tensor::frob_dist_sq;
use smoothrot::transforms::{self, Mode, RotationCache};

/// Scalar plus every SIMD backend this host detects.
fn kernel_backends() -> Vec<KernelBackend> {
    let mut v = vec![KernelBackend::Scalar];
    v.extend([KernelBackend::Avx2, KernelBackend::Neon].into_iter().filter(|b| b.available()));
    v
}

#[test]
fn prop_igemm_matches_qdq_then_matmul_reference() {
    check("igemm == qdq(X) @ qdq(W) within 1e-4 rel frobenius", 40, |g| {
        let m = g.usize_in(1, 24);
        let k = g.usize_in(1, 96);
        let n = g.usize_in(1, 24);
        let bits = *g.choose(&[4u32, 8]);
        let threads = g.usize_in(1, 4);
        let x = g.matrix(m, k);
        let w = g.matrix(k, n);
        let want = quant::qdq(&x, bits, Granularity::PerToken)
            .matmul(&quant::qdq(&w, bits, Granularity::PerChannel));
        let mut ws = Workspace::new();
        // the f32 reference is backend-free, so every kernel backend's
        // quantize must land on the same grid
        for be in kernel_backends() {
            let (qx, got) = simd::with_backend(be, || {
                let qx = QMatrix::quantize(&x, bits, ScaleAxis::PerRow)?;
                let qw = QMatrix::quantize(&w, bits, ScaleAxis::PerCol)?;
                let got = igemm(&qx, &qw, &mut ws, threads)?;
                Ok::<_, String>((qx, got))
            })?;
            // 4-bit operands take the packed-i4 storage path
            ensure(qx.is_packed() == (bits == 4), "storage kind follows bits")?;
            let dist = frob_dist_sq(want.as_slice(), got.as_slice()).sqrt();
            let rel = dist / want.frob().max(1e-9);
            ensure(
                rel <= 1e-4,
                format!("be={be} m={m} k={k} n={n} bits={bits} threads={threads}: rel {rel}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_dequantize_bit_identical_to_qdq_both_granularities() {
    check("QMatrix::dequantize == quant::qdq bit for bit", 40, |g| {
        let rows = g.usize_in(1, 20);
        let cols = g.usize_in(1, 40);
        let bits = *g.choose(&[2u32, 4, 8]);
        let x = g.matrix(rows, cols);
        for (axis, gran) in [
            (ScaleAxis::PerRow, Granularity::PerToken),
            (ScaleAxis::PerCol, Granularity::PerChannel),
        ] {
            let want = quant::qdq(&x, bits, gran);
            // qdq is the scalar f32 reference: a SIMD quantize that
            // rounds even one tie differently fails this bit-for-bit
            for be in kernel_backends() {
                let q = simd::with_backend(be, || QMatrix::quantize(&x, bits, axis))?;
                ensure(
                    q.dequantize().as_slice() == want.as_slice(),
                    format!("be={be} bits={bits} axis={axis:?}: dequantize drifted from qdq"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_i4_pack_unpack_roundtrip_identity() {
    check("pack_i4 . unpack_i4 == id over random nibble values", 50, |g| {
        let len = g.usize_in(1, 200);
        let vals: Vec<i8> = (0..len).map(|_| g.usize_in(0, 15) as i8 - 8).collect();
        let packed = pack_i4(&vals);
        ensure(packed.len() == (len + 1) / 2, "packed length")?;
        let mut got = vec![0i8; len];
        unpack_i4(&packed, len, &mut got);
        ensure(got == vals, format!("roundtrip drifted at len {len}"))
    });
}

#[test]
fn prop_igemm_thread_count_is_exactly_invariant() {
    check("igemm bit-identical at every thread count", 25, |g| {
        let m = g.usize_in(1, 32);
        let k = g.usize_in(1, 64);
        let n = g.usize_in(1, 16);
        let bits = *g.choose(&[4u32, 8]);
        let x = g.matrix(m, k);
        let w = g.matrix(k, n);
        let qx = QMatrix::quantize(&x, bits, ScaleAxis::PerRow)?;
        let qw = QMatrix::quantize(&w, bits, ScaleAxis::PerCol)?;
        let mut ws = Workspace::new();
        let serial = igemm(&qx, &qw, &mut ws, 1)?;
        for threads in [2usize, 3, 7, 64] {
            let par = igemm(&qx, &qw, &mut ws, threads)?;
            // integer accumulation is associative, so this is exact
            // equality, not a tolerance
            ensure(
                par.as_slice() == serial.as_slice(),
                format!("threads={threads} diverged from serial"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_planned_int_tracks_planned_f32_across_modes() {
    check("analyze_planned_int error ~ analyze_planned error", 20, |g| {
        let n = g.usize_in(2, 20);
        let c_in = *g.choose(&[8usize, 16, 32, 64]);
        let c_out = g.usize_in(2, 12);
        let bits = *g.choose(&[4u32, 8]);
        let alpha = g.f32_in(0.2, 0.8);
        let x = g.matrix(n, c_in);
        let w = g.matrix(c_in, c_out);
        let s = transforms::smooth_scales(&x, &w, alpha);
        let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        let threads = g.usize_in(1, 3);
        for mode in Mode::ALL {
            let smooth =
                matches!(mode, Mode::Smooth | Mode::SmoothRotate).then_some((&s[..], &inv[..]));
            let rot = if matches!(mode, Mode::Rotate | Mode::SmoothRotate) {
                Some(cache.get(c_in)?.clone())
            } else {
                None
            };
            let sim = analyze_planned(&x, &w, bits, mode, smooth, rot.as_ref(), &mut ws, threads)?;
            let pw =
                PlannedWeight::from_plan(&w, smooth.map(|(s, _)| s), rot.as_ref(), bits, threads)?;
            for be in kernel_backends() {
                let exec = simd::with_backend(be, || {
                    analyze_planned_int(
                        &x,
                        &w,
                        bits,
                        mode,
                        smooth,
                        rot.as_ref(),
                        &pw,
                        &mut ws,
                        threads,
                    )
                })?;
                let i = mode.index();
                close(sim.errors[i], exec.errors[i], 1e-2, &format!("{mode:?} {be} exec error"))?;
                for j in 0..4 {
                    if j != i {
                        ensure(
                            exec.errors[j].is_infinite(),
                            format!("{mode:?} {be} slot {j} finite"),
                        )?;
                    }
                }
            }
        }
        Ok(())
    });
}
