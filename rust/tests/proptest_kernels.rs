//! Property tests over the fused kernel engine (check = proptest-lite):
//! FWHT-vs-dense rotation agreement, fused-vs-naive analyze agreement,
//! thread-count invariance, and workspace steady-state reuse.

use smoothrot::check::{check, close, ensure};
use smoothrot::coordinator::NativeExecutor;
use smoothrot::kernels::fused::analyze_all_modes;
use smoothrot::kernels::fwht::{fwht, FwhtPlan};
use smoothrot::kernels::workspace::Workspace;
use smoothrot::transforms::{self, RotationCache};

#[test]
fn prop_fwht_matches_dense_sylvester_2_to_256() {
    check("fwht == x @ H_sylvester / sqrt(d) for d in {2..256}", 30, |g| {
        let d = *g.choose(&[2usize, 4, 8, 16, 32, 64, 128, 256]);
        let x: Vec<f32> = g.normals(d);
        // tolerance scaled by the row magnitude: individual output
        // components can legitimately cancel to near zero
        let norm: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt().max(1.0);
        // unnormalized butterfly vs dense H
        let mut got = x.clone();
        fwht(&mut got);
        let h = transforms::sylvester(d)?;
        for j in 0..d {
            let want: f64 = (0..d).map(|i| x[i] as f64 * h.get(i, j) as f64).sum();
            ensure(
                (got[j] as f64 - want).abs() <= 1e-4 * norm * (d as f64).sqrt(),
                format!("fwht d={d} col {j}: {} vs {want}", got[j]),
            )?;
        }
        // normalized plan vs dense R = H / sqrt(d)
        let plan = FwhtPlan::new(d).ok_or("plan must exist for powers of two")?;
        let mut rotated = x.clone();
        plan.apply_row(&mut rotated);
        let scale = 1.0 / (d as f64).sqrt();
        for j in 0..d {
            let want: f64 =
                (0..d).map(|i| x[i] as f64 * h.get(i, j) as f64).sum::<f64>() * scale;
            ensure(
                (rotated[j] as f64 - want).abs() <= 1e-4 * norm,
                format!("plan d={d} col {j}: {} vs {want}", rotated[j]),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_fwht_plan_matches_dense_rotation_mixed_widths() {
    check("kronecker FWHT == dense rotation for paley widths", 20, |g| {
        let d = *g.choose(&[44usize, 88, 176, 352]);
        let plan = FwhtPlan::new(d).ok_or("plan must exist")?;
        let x: Vec<f32> = g.normals(d);
        let norm: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt().max(1.0);
        let mut got = x.clone();
        plan.apply_row(&mut got);
        let r = transforms::rotation(d)?;
        for j in 0..d {
            let want: f64 = (0..d).map(|i| x[i] as f64 * r.get(i, j) as f64).sum();
            ensure(
                (got[j] as f64 - want).abs() <= 1e-4 * norm,
                format!("d={d} col {j}: {} vs {want}", got[j]),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_fused_analyze_matches_naive_per_mode() {
    check("analyze_all_modes == naive per-mode analyze (1e-4 rel)", 20, |g| {
        let n = g.usize_in(2, 32);
        let c_in = *g.choose(&[8usize, 16, 32, 44, 64, 88]);
        let c_out = g.usize_in(2, 24);
        let bits = *g.choose(&[2u32, 3, 4, 8]);
        let alpha = g.f32_in(0.1, 0.9);
        let x = g.matrix(n, c_in);
        let w = g.matrix(c_in, c_out);
        let naive = NativeExecutor::analyze_naive(&x, &w, bits, alpha)?;
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        let threads = g.usize_in(1, 4);
        let fused = analyze_all_modes(&x, &w, bits, alpha, &mut cache, &mut ws, threads)?;
        for i in 0..4 {
            close(fused.errors[i], naive.errors[i], 1e-4, &format!("errors[{i}]"))?;
            close(
                fused.act_difficulty[i],
                naive.act_difficulty[i],
                1e-4,
                &format!("act_difficulty[{i}]"),
            )?;
            close(fused.w_difficulty[i], naive.w_difficulty[i], 1e-4, &format!("w_difficulty[{i}]"))?;
            close(fused.act_absmax[i], naive.act_absmax[i], 1e-4, &format!("act_absmax[{i}]"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_threaded_matmul_bit_identical_to_serial() {
    check("matmul_threaded == matmul at every thread count", 25, |g| {
        let m = g.usize_in(1, 24);
        let k = g.usize_in(1, 96);
        let n = g.usize_in(1, 24);
        let a = g.matrix(m, k);
        let b = g.matrix(k, n);
        let serial = a.matmul(&b);
        let threads = g.usize_in(0, 6); // 0 exercises the auto path
        let par = a.matmul_threaded(&b, threads);
        ensure(par.as_slice() == serial.as_slice(), format!("threads={threads} diverged"))?;
        let ts = a.transpose_threaded(threads);
        ensure(ts.as_slice() == a.transpose().as_slice(), "transpose diverged")
    });
}

#[test]
fn prop_workspace_steady_state_never_allocates() {
    check("warm workspace serves analyze without allocating", 8, |g| {
        let n = g.usize_in(4, 24);
        let c_in = *g.choose(&[16usize, 32, 64]);
        let c_out = g.usize_in(2, 16);
        let x = g.matrix(n, c_in);
        let w = g.matrix(c_in, c_out);
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        for _ in 0..3 {
            analyze_all_modes(&x, &w, 4, 0.5, &mut cache, &mut ws, 1)?;
        }
        let (_, warm_allocs) = ws.stats();
        for _ in 0..3 {
            analyze_all_modes(&x, &w, 4, 0.5, &mut cache, &mut ws, 1)?;
        }
        let (reuses, allocs) = ws.stats();
        ensure(allocs == warm_allocs, format!("allocated {} buffers warm", allocs - warm_allocs))?;
        ensure(reuses > 0, "workspace never reused a buffer")?;
        // the rotation was built exactly once across all six calls
        let s = cache.stats();
        ensure(s.misses == 1 && s.hits == 5, format!("cache stats {s:?}"))
    });
}

#[test]
fn rotation_cache_serves_pow2_widths_via_fwht() {
    let mut cache = RotationCache::new();
    for d in [8usize, 64, 256, 704] {
        assert!(cache.get(d).unwrap().is_fwht(), "d={d} must take the FWHT path");
    }
    assert_eq!(cache.len(), 4);
}
