//! Property tests over the native math substrates (check = proptest-lite).

use smoothrot::check::{check, close, ensure, Gen};
use smoothrot::metrics::{self, Channels};
use smoothrot::outlier::OutlierToken;
use smoothrot::quant::{self, Granularity};
use smoothrot::tensor::Matrix;
use smoothrot::transforms::{self, Mode};

fn random_dims(g: &mut Gen) -> (usize, usize, usize) {
    let n = g.usize_in(2, 48);
    let c_in = *g.choose(&[8usize, 16, 32, 44, 64, 88]);
    let c_out = g.usize_in(2, 32);
    (n, c_in, c_out)
}

#[test]
fn prop_transforms_preserve_product() {
    check("XW == Xh Wh for every mode", 40, |g| {
        let (n, c_in, c_out) = random_dims(g);
        let x = g.matrix(n, c_in);
        let w = g.matrix(c_in, c_out);
        let y = x.matmul(&w);
        let mode = *g.choose(&Mode::ALL);
        let (xh, wh) = transforms::apply(mode, &x, &w, g.f32_in(0.1, 0.9)).map_err(|e| e)?;
        let yh = xh.matmul(&wh);
        let scale = (y.abs_max() as f64).max(1.0);
        for (a, b) in y.as_slice().iter().zip(yh.as_slice()) {
            ensure(
                ((a - b).abs() as f64) / scale < 5e-4,
                format!("{mode:?}: {a} vs {b}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_rotation_preserves_norms() {
    check("rotation is an isometry", 40, |g| {
        let n = g.usize_in(1, 32);
        let d = *g.choose(&[16usize, 44, 64, 88, 128]);
        let x = g.matrix(n, d);
        let r = transforms::rotation(d)?;
        let xr = x.matmul(&r);
        close(xr.frob(), x.frob(), 1e-5, "frobenius")?;
        // per-row norms preserved too
        for i in 0..n {
            let a: f64 = x.row(i).iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
            let b: f64 = xr.row(i).iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
            close(a, b, 1e-4, "row norm")?;
        }
        Ok(())
    });
}

#[test]
fn prop_qdq_idempotent_and_bounded() {
    check("Q(Q(X)) == Q(X), |X - Q(X)| <= Delta/2", 60, |g| {
        let n = g.usize_in(1, 32);
        let c = g.usize_in(1, 64);
        let bits = *g.choose(&[2u32, 3, 4, 8]);
        let mut x = g.matrix(n, c);
        // occasionally inject a massive outlier
        if g.usize_in(0, 3) == 0 {
            let i = g.usize_in(0, n - 1);
            let j = g.usize_in(0, c - 1);
            x.set(i, j, 5000.0);
        }
        let q1 = quant::qdq(&x, bits, Granularity::PerToken);
        let q2 = quant::qdq(&q1, bits, Granularity::PerToken);
        for (a, b) in q1.as_slice().iter().zip(q2.as_slice()) {
            ensure((a - b).abs() < 1e-4 * a.abs().max(1.0), format!("idempotence {a} vs {b}"))?;
        }
        let deltas = quant::token_scales(&x, bits);
        for i in 0..n {
            for j in 0..c {
                let err = (x.get(i, j) - q1.get(i, j)).abs();
                ensure(err <= deltas[i] / 2.0 + 1e-5, format!("rounding error {err} > Delta/2"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fused_error_matches_reference() {
    check("fused qerror == two-matmul qerror", 30, |g| {
        let (n, c_in, c_out) = random_dims(g);
        let x = g.matrix(n, c_in);
        let w = g.matrix(c_in, c_out);
        let a = quant::quant_error(&x, &w, 4);
        let b = quant::quant_error_fused(&x, &w, 4);
        close(a, b, 1e-4, "fused vs reference")
    });
}

#[test]
fn prop_smoothing_migration_identity() {
    check("alpha=0.5 equalizes channel maxima", 30, |g| {
        let (n, c_in, c_out) = random_dims(g);
        let x = g.matrix(n, c_in);
        let w = g.matrix(c_in, c_out);
        let s = transforms::smooth_scales(&x, &w, 0.5);
        let (xh, wh) = transforms::smooth_apply(&x, &w, &s);
        let xmax = x.col_abs_max();
        let xhmax = xh.col_abs_max();
        for j in 0..c_in {
            let wmax = w.row(j).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let want = (xmax[j] * wmax).sqrt();
            close(xhmax[j] as f64, want as f64, 1e-3, "X_hat channel max")?;
            let whmax = wh.row(j).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            close(whmax as f64, want as f64, 1e-3, "W_hat channel max")?;
        }
        Ok(())
    });
}

#[test]
fn prop_eq8_rotated_outlier_max() {
    // Eq. 8 gives max|t_hat| = sum|o|/sqrt(d) + |eps| — attained exactly
    // when a Hadamard column sign-aligns with ALL outlier dims.  That
    // column is essentially guaranteed for Sylvester when d >> 2^|O|
    // (columns realize every sign pattern), but for the Paley-Kronecker
    // H704, or when 2^|O| ~ d, only the upper bound is sound plus the
    // best-available-centroid lower bound.
    check("Eq. 8: max|t_hat| bounded by sum|o|/sqrt(d)", 30, |g| {
        let d = *g.choose(&[64usize, 128, 256, 704]);
        let n_out = g.usize_in(1, 6);
        let sigma = g.f32_in(0.05, 1.0);
        let tok = OutlierToken::sample(d, n_out, g.f32_in(800.0, 4000.0), sigma, &mut g.rng);
        let t = tok.materialize(&mut g.rng);
        let x = Matrix::from_vec(1, d, t);
        let r = transforms::rotation(d)?;
        let got = x.matmul(&r).abs_max() as f64;
        let want = tok.predicted_rotated_max();
        let noise = 6.0 * sigma as f64;
        ensure(got <= want + noise, format!("got {got} exceeds Eq.8 bound {want}"))?;
        // the achieved max is at least the second-best centroid
        let centroids = tok.centroid_magnitudes()?;
        let floor = if centroids.len() >= 2 { centroids[centroids.len() - 2] } else { want };
        ensure(
            got >= floor - noise,
            format!("got {got} below the second centroid {floor} (Eq.7 violated)"),
        )?;
        // exact Eq. 8 for the well-covered Sylvester regime
        if d.is_power_of_two() && (1usize << n_out) * 8 <= d {
            ensure(
                (got - want).abs() < noise,
                format!("Sylvester d={d}, |O|={n_out}: got {got}, Eq.8 predicts {want}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_difficulty_scale_invariance_structure() {
    check("difficulty scales linearly; rotation flattens hot channels", 30, |g| {
        let n = g.usize_in(4, 32);
        let d = *g.choose(&[32usize, 64, 128]);
        let mut x = g.matrix(n, d);
        let k = g.f32_in(1.5, 10.0);
        // difficulty is homogeneous of degree 1 in the data
        let mut x2 = x.clone();
        for v in x2.as_mut_slice() {
            *v *= k;
        }
        let d1 = metrics::quant_difficulty(&x, Channels::Columns);
        let d2 = metrics::quant_difficulty(&x2, Channels::Columns);
        close(d2, (k as f64) * d1, 1e-4, "homogeneity")?;
        // hot channel -> rotation drops difficulty substantially (the
        // residual spread scales with 1/sqrt(n); small token counts keep
        // more variance, so assert a conservative 2x)
        let hot = g.usize_in(0, d - 1);
        for i in 0..n {
            x.row_mut(i)[hot] *= 60.0;
        }
        let r = transforms::rotation(d)?;
        let xr = x.matmul(&r);
        ensure(
            metrics::quant_difficulty(&xr, Channels::Columns)
                < 0.5 * metrics::quant_difficulty(&x, Channels::Columns),
            "rotation must flatten a hot channel",
        )
    });
}

#[test]
fn prop_pearson_bounds_and_symmetry() {
    check("|pearson| <= 1 and corr(x,x) == 1", 40, |g| {
        let n = g.usize_in(3, 64);
        let xs: Vec<f64> = (0..n).map(|_| g.rng.normal()).collect();
        let ys: Vec<f64> = (0..n).map(|_| g.rng.normal()).collect();
        let c = metrics::pearson(&xs, &ys);
        ensure(c.abs() <= 1.0 + 1e-12, format!("corr {c} out of bounds"))?;
        close(metrics::pearson(&xs, &xs), 1.0, 1e-9, "self correlation")?;
        close(metrics::pearson(&xs, &ys), metrics::pearson(&ys, &xs), 1e-12, "symmetry")
    });
}
