//! Property tests over the quant-plan artifact (check = proptest-lite).
//!
//! Over random plans: serialize → parse is the identity (pretty and
//! compact forms), the canonical serialization is a fixed point, a
//! bumped schema version is rejected, and value tampering breaks the
//! content hash.

use smoothrot::calib::plan::{PlanEntry, Provenance, QuantPlan, PLAN_SCHEMA_VERSION};
use smoothrot::check::{check, ensure, Gen};
use smoothrot::transforms::Mode;

fn random_plan(g: &mut Gen) -> QuantPlan {
    let n = g.usize_in(0, 10);
    let entries = (0..n)
        .map(|layer| {
            let module = (*g.choose(&smoothrot::MODULES)).to_string();
            let mode = *g.choose(&Mode::ALL);
            let c_in = g.usize_in(1, 48);
            let smooth = matches!(mode, Mode::Smooth | Mode::SmoothRotate)
                .then(|| (0..c_in).map(|_| g.f32_in(1e-3, 100.0)).collect());
            PlanEntry {
                module,
                layer,
                bits: *g.choose(&[2u32, 3, 4, 8, 16]),
                c_in,
                mode,
                alpha: g.f32_in(0.0, 1.0),
                predicted_error: g.f32_in(0.0, 1e6) as f64,
                difficulty_before: g.f32_in(0.0, 1e3) as f64,
                difficulty_after: g.f32_in(0.0, 1e3) as f64,
                smooth,
            }
        })
        .collect();
    QuantPlan {
        provenance: Provenance {
            // exercise the full u64 range (the artifact stores the
            // seed as a decimal string to survive the f64 model)
            seed: (g.rng.next_u64() << 1) | (g.usize_in(0, 1) as u64),
            alphas: (0..g.usize_in(1, 3)).map(|_| g.f32_in(0.0, 1.0) as f64).collect(),
            bits_grid: vec![4],
            sr_margin: g.f32_in(1.0, 2.0) as f64,
            threads: g.usize_in(0, 8),
            ..Provenance::default()
        },
        entries,
    }
}

#[test]
fn prop_plan_roundtrip_is_identity() {
    check("quant plan: serialize -> parse is the identity", 40, |g| {
        let plan = random_plan(g);
        let pretty = plan.to_json_string();
        let back = QuantPlan::parse(&pretty).map_err(|e| format!("pretty parse: {e}"))?;
        ensure(back == plan, "pretty round-trip changed the plan")?;
        let compact = plan.to_json().to_string_compact();
        let back = QuantPlan::parse(&compact).map_err(|e| format!("compact parse: {e}"))?;
        ensure(back == plan, "compact round-trip changed the plan")?;
        // canonical serialization is a fixed point (and so is the hash)
        ensure(back.to_json_string() == pretty, "re-serialization drifted")?;
        ensure(back.content_hash() == plan.content_hash(), "content hash drifted")
    });
}

#[test]
fn prop_bumped_schema_version_is_rejected() {
    check("quant plan: a newer schema version is refused", 20, |g| {
        let plan = random_plan(g);
        let needle = format!("\"version\": {PLAN_SCHEMA_VERSION}");
        let bumped = g.usize_in(PLAN_SCHEMA_VERSION as usize + 1, 2_000_000);
        let text = plan.to_json_string().replacen(&needle, &format!("\"version\": {bumped}"), 1);
        match QuantPlan::parse(&text) {
            Ok(_) => Err(format!("version {bumped} must be rejected")),
            Err(e) => ensure(
                e.contains("newer than supported"),
                format!("wrong rejection message: {e}"),
            ),
        }
    });
}

#[test]
fn prop_value_tampering_breaks_the_hash() {
    check("quant plan: edited values fail the content hash", 20, |g| {
        let mut plan = random_plan(g);
        // ensure at least one entry with a recognizable value to edit
        plan.entries.push(PlanEntry {
            module: "k_proj".into(),
            layer: 999,
            bits: 4,
            c_in: 2,
            mode: Mode::None,
            alpha: 0.5,
            predicted_error: 123456.75,
            difficulty_before: 1.0,
            difficulty_after: 1.0,
            smooth: None,
        });
        let text = plan.to_json_string();
        ensure(text.contains("123456.75"), "marker value must serialize verbatim")?;
        let tampered = text.replacen("123456.75", "123456.875", 1);
        match QuantPlan::parse(&tampered) {
            Ok(_) => Err("tampered plan must not parse".into()),
            Err(e) => {
                ensure(e.contains("content hash mismatch"), format!("wrong error: {e}"))
            }
        }
    });
}
