//! Property tests over the serving-core invariants (check = proptest-lite).
//!
//! Over random tenant mixes, worker counts, batch limits and pause
//! modes: every admitted request completes exactly once; no batch
//! exceeds `max_batch` or mixes incompatible keys; per-tenant counters
//! reconcile with the stream.

use smoothrot::check::{check, ensure, Gen};
use smoothrot::coordinator::{Executor, Job};
use smoothrot::runtime::AnalyzeOut;
use smoothrot::serve::{serve_all, BatchKey, ServeConfig};
use smoothrot::tensor::Matrix;

/// Executor that encodes job identity into its output.
struct EchoExec;

impl Executor for EchoExec {
    fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
        let mut out = AnalyzeOut::default();
        out.errors[0] = job.id as f64;
        Ok(out)
    }
}

fn make_requests(g: &mut Gen, n: usize, tenants: usize) -> Vec<(usize, Job)> {
    (0..n)
        .map(|i| {
            let module = *g.choose(&smoothrot::MODULES);
            let bits = *g.choose(&[4u32, 8]);
            let job = Job {
                id: i as u64,
                layer: g.usize_in(0, 7),
                module,
                x: Matrix::zeros(2, 4),
                w: Matrix::zeros(4, 2),
                alpha: 0.5,
                bits,
            };
            (g.usize_in(0, tenants - 1), job)
        })
        .collect()
}

#[test]
fn prop_serving_core_invariants() {
    check("serving core: exactly-once, key-pure bounded batches", 20, |g| {
        let n = g.usize_in(1, 60);
        let tenants = g.usize_in(1, 4);
        let cfg = ServeConfig {
            workers: g.usize_in(1, 4),
            max_batch: g.usize_in(1, 6),
            queue_depth: 64, // >= n: Block admission never stalls a paused run
            paused: g.usize_in(0, 1) == 1,
            ..ServeConfig::default()
        };
        let requests = make_requests(g, n, tenants);
        let keys: Vec<BatchKey> = requests.iter().map(|(_, j)| BatchKey::of(j)).collect();
        let submitted_per_tenant: Vec<usize> =
            (0..tenants).map(|t| requests.iter().filter(|(rt, _)| *rt == t).count()).collect();

        let (responses, metrics) =
            serve_all(cfg, requests, |_| Ok(EchoExec)).map_err(|e| e.to_string())?;

        ensure(responses.len() == n, "response count mismatch")?;
        ensure(metrics.completed as usize == n, "metrics.completed mismatch")?;
        ensure(metrics.rejected == 0, "nothing may be rejected at this depth")?;

        // exactly once, correctly keyed
        let mut seen = vec![false; n];
        for r in &responses {
            let idx = r.id as usize;
            ensure(idx < n && !seen[idx], format!("request {idx} duplicated or unknown"))?;
            seen[idx] = true;
            let out = r.out.as_ref().map_err(|e| format!("request {idx} errored: {e}"))?;
            ensure(out.errors[0] as u64 == r.id, "result not keyed to its request")?;
        }

        // batches: bounded, key-homogeneous, sizes consistent
        let mut by_batch: std::collections::BTreeMap<u64, Vec<&smoothrot::serve::Response>> =
            std::collections::BTreeMap::new();
        for r in &responses {
            by_batch.entry(r.batch_id).or_default().push(r);
        }
        ensure(by_batch.len() as u64 == metrics.batches, "batch count mismatch")?;
        for (id, members) in &by_batch {
            ensure(members.len() <= cfg.max_batch, format!("batch {id} exceeds max_batch"))?;
            let first = &keys[members[0].id as usize];
            for m in members {
                ensure(keys[m.id as usize] == *first, format!("batch {id} mixes keys"))?;
                ensure(m.batch_size == members.len(), "batch_size field inconsistent")?;
            }
        }
        ensure(
            metrics.max_batch_observed == by_batch.values().map(Vec::len).max().unwrap_or(0),
            "max_batch_observed mismatch",
        )?;

        // per-tenant accounting reconciles with the submitted stream
        for (t, &want) in submitted_per_tenant.iter().enumerate() {
            let got = metrics.per_tenant.get(&t).map(|s| s.completed).unwrap_or(0);
            ensure(got as usize == want, format!("tenant {t}: completed {got}, want {want}"))?;
        }
        ensure(
            metrics.per_worker_batches.iter().sum::<u64>() == metrics.batches,
            "per-worker batch counts don't sum to total",
        )
    });
}
