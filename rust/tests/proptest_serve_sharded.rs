//! Property test for the sharded-serving invariance contract
//! (check = proptest-lite): per-job responses are **identical** across
//! runner counts {1, 2, 4}, with work stealing forced on and off, and
//! across both shard keys.  Sharding only changes *placement* — which
//! runner executes a batch — never kernel math, so the full per-job
//! [`AnalyzeOut`] (Eq. 2 errors, difficulty, absmax) must match the
//! single-runner baseline bit for bit.

use std::collections::BTreeMap;
use std::sync::Arc;

use smoothrot::calib::plan::{PlanEntry, Provenance, QuantPlan};
use smoothrot::calib::registry::PlanRegistry;
use smoothrot::check::{check, ensure, Gen};
use smoothrot::coordinator::Job;
use smoothrot::rng::Rng;
use smoothrot::runtime::AnalyzeOut;
use smoothrot::serve::shard::{serve_all_sharded, ShardBy, ShardConfig};
use smoothrot::serve::{ExecMode, NativeBatchExecutor, Response, ServeConfig};
use smoothrot::tensor::Matrix;
use smoothrot::transforms::Mode;

const C_IN: usize = 16;
const C_OUT: usize = 8;
const LAYERS: usize = 4;

/// Deterministic per-layer serving weight (shared by the plan preload
/// and the jobs, like the CLI's `synth::layer_weight` contract).
fn weight(layer: usize) -> Matrix {
    let mut rng = Rng::new(7000 + layer as u64);
    Matrix::from_vec(C_IN, C_OUT, rng.normals_f32(C_IN * C_OUT))
}

/// A fresh int8-preloaded registry over k_proj layers 0..LAYERS.
/// Each serving config gets its own registry so counters and caches
/// never leak between the baseline and the sharded runs.
fn registry() -> Arc<PlanRegistry> {
    let plan = QuantPlan {
        provenance: Provenance::default(),
        entries: (0..LAYERS)
            .map(|layer| PlanEntry {
                module: "k_proj".into(),
                layer,
                bits: 4,
                c_in: C_IN,
                mode: Mode::Rotate,
                alpha: 0.5,
                predicted_error: 1.0,
                difficulty_before: 2.0,
                difficulty_after: 1.0,
                smooth: None,
            })
            .collect(),
    };
    let reg = Arc::new(PlanRegistry::from_plan(&plan).unwrap());
    reg.set_weight_provider(Box::new(|module, layer| {
        (module == "k_proj" && layer < LAYERS).then(|| weight(layer))
    }))
    .unwrap();
    reg
}

fn make_requests(g: &mut Gen, n: usize, tenants: usize) -> Vec<(usize, Job)> {
    (0..n)
        .map(|i| {
            let layer = g.usize_in(0, LAYERS - 1);
            let rows = g.usize_in(1, 5);
            let mut rng = Rng::new(8000 + i as u64);
            let job = Job {
                id: i as u64,
                layer,
                module: "k_proj",
                x: Matrix::from_vec(rows, C_IN, rng.normals_f32(rows * C_IN)),
                w: weight(layer),
                alpha: 0.5,
                bits: 4,
            };
            (g.usize_in(0, tenants - 1), job)
        })
        .collect()
}

fn by_id(responses: &[Response]) -> Result<BTreeMap<u64, AnalyzeOut>, String> {
    responses
        .iter()
        .map(|r| match &r.out {
            Ok(out) => Ok((r.id, out.clone())),
            Err(e) => Err(format!("request {} errored: {e}", r.id)),
        })
        .collect()
}

#[test]
fn prop_runner_count_and_stealing_never_change_results() {
    check("sharded serving: per-job outputs invariant in runner count x stealing", 12, |g| {
        let n = g.usize_in(4, 40);
        let tenants = g.usize_in(1, 3);
        let max_batch = g.usize_in(1, 6);
        let shard_by = *g.choose(&[ShardBy::Layer, ShardBy::Tenant]);
        let exec = *g.choose(&[ExecMode::F32, ExecMode::Int8]);
        let requests = make_requests(g, n, tenants);
        let base = ServeConfig {
            workers: 1,
            max_batch,
            queue_depth: 64, // >= n: Block admission never stalls a paused run
            paused: true,
            ..ServeConfig::default()
        };

        // 1-runner, stealing off: the reference placement-free run
        let baseline = {
            let reg = registry();
            let cfg = ShardConfig { runners: 1, shard_by, stealing: false, base };
            let (responses, m) = serve_all_sharded(cfg, requests.clone(), move |_| {
                Ok(NativeBatchExecutor::with_plan_exec(Arc::clone(&reg), 1, exec))
            })
            .map_err(|e| e.to_string())?;
            ensure(m.completed as usize == n, "baseline lost requests")?;
            by_id(&responses)?
        };
        ensure(baseline.len() == n, "baseline response ids not unique")?;

        for runners in [2usize, 4] {
            for stealing in [false, true] {
                let reg = registry();
                let r2 = Arc::clone(&reg);
                let cfg = ShardConfig { runners, shard_by, stealing, base };
                let (responses, m) = serve_all_sharded(cfg, requests.clone(), move |_| {
                    Ok(NativeBatchExecutor::with_plan_exec(Arc::clone(&r2), 1, exec))
                })
                .map_err(|e| e.to_string())?;
                let label = format!("runners {runners} stealing {stealing}");
                ensure(m.completed as usize == n, format!("{label}: lost requests"))?;
                ensure(
                    m.per_worker_routed.iter().sum::<u64>() == m.batches,
                    format!("{label}: routed counters don't cover every batch"),
                )?;
                if !stealing {
                    ensure(m.steals == 0, format!("{label}: stole with stealing off"))?;
                }
                if exec == ExecMode::Int8 {
                    let (executed, degraded) = reg.int8_stats();
                    ensure(
                        executed as usize == n && degraded == 0,
                        format!("{label}: int8 path degraded ({executed}/{degraded})"),
                    )?;
                }
                let got = by_id(&responses)?;
                ensure(got.len() == n, format!("{label}: response ids not unique"))?;
                for (id, want) in &baseline {
                    let out = &got[id];
                    ensure(
                        out == want,
                        format!("{label}: job {id} diverged from the 1-runner baseline"),
                    )?;
                }
            }
        }
        Ok(())
    });
}
