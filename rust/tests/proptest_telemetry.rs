//! Property tests over the telemetry subsystem (check = proptest-lite).
//!
//! Over random observation streams and random snapshots: registry
//! snapshots are identical no matter how many worker threads produced
//! the observations, counter totals are exact under any split of the
//! adds, histogram bucket counts conserve the observation count, the
//! Prometheus exposition round-trips through the minimal parser, and
//! the JSON artifact enforces the same schema-version ceiling as the
//! calibration plan.

use smoothrot::check::{check, ensure, Gen};
use smoothrot::telemetry::difficulty::{Cell, DifficultyRow};
use smoothrot::telemetry::export::{CounterRow, GaugeRow, HistogramRow};
use smoothrot::telemetry::registry::Labels;
use smoothrot::telemetry::{Registry, Snapshot, TELEMETRY_SCHEMA_VERSION};

const COUNTERS: [&str; 3] = ["reqs_total", "batches_total", "steals_total"];
const HISTS: [&str; 2] = ["transform_seconds", "igemm_seconds"];
const BOUNDS: &[f64] = &[1e-6, 1e-4, 1e-2, 1.0];

/// One registry observation, replayable across any thread split.
#[derive(Clone, Copy)]
enum Op {
    Count(usize, u64),
    Observe(usize, u64),
}

/// Replay `ops` round-robin across `threads` worker threads and
/// snapshot the resulting registry.
fn apply(ops: &[Op], threads: usize) -> Snapshot {
    let reg = Registry::new();
    std::thread::scope(|s| {
        for t in 0..threads {
            let reg = &reg;
            s.spawn(move || {
                for (i, op) in ops.iter().enumerate() {
                    if i % threads != t {
                        continue;
                    }
                    match *op {
                        Op::Count(k, n) => reg.counter(COUNTERS[k], &[]).add(n),
                        Op::Observe(k, ns) => {
                            reg.histogram(HISTS[k], &[], BOUNDS)
                                .expect("fixed valid bounds")
                                .observe_ns(ns);
                        }
                    }
                }
            });
        }
    });
    let mut snap = Snapshot::new();
    reg.snapshot_into(&mut snap);
    snap
}

#[test]
fn prop_snapshots_are_worker_count_invariant() {
    check("telemetry: snapshots do not depend on the worker count", 25, |g| {
        let n = g.usize_in(1, 120);
        let ops: Vec<Op> = (0..n)
            .map(|_| {
                if g.usize_in(0, 1) == 0 {
                    Op::Count(g.usize_in(0, COUNTERS.len() - 1), g.usize_in(0, 1_000_000) as u64)
                } else {
                    Op::Observe(
                        g.usize_in(0, HISTS.len() - 1),
                        g.usize_in(0, 5_000_000_000) as u64,
                    )
                }
            })
            .collect();
        let base = apply(&ops, 1);
        for workers in [2usize, 4] {
            ensure(
                apply(&ops, workers) == base,
                format!("snapshot diverged at {workers} workers"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_counter_totals_are_exact_under_any_split() {
    check("telemetry: counter adds sum exactly under any thread split", 25, |g| {
        let parts: Vec<u64> =
            (0..g.usize_in(1, 64)).map(|_| g.usize_in(0, 1_000_000) as u64).collect();
        let total: u64 = parts.iter().sum();
        let threads = *g.choose(&[1usize, 2, 3, 4, 8]);
        let reg = Registry::new();
        let c = reg.counter("ops_total", &[]);
        std::thread::scope(|s| {
            for t in 0..threads {
                let c = &c;
                let parts = &parts;
                s.spawn(move || {
                    for (i, &n) in parts.iter().enumerate() {
                        if i % threads == t {
                            c.add(n);
                        }
                    }
                });
            }
        });
        ensure(c.value() == total, format!("counter read {} != exact total {total}", c.value()))
    });
}

#[test]
fn prop_histogram_buckets_conserve_the_count() {
    check("telemetry: bucket counts conserve the observation count", 25, |g| {
        let mut bounds: Vec<f64> =
            (0..g.usize_in(1, 6)).map(|_| g.f32_in(1e-6, 2.0) as f64).collect();
        bounds.sort_by(f64::total_cmp);
        bounds.dedup();
        let reg = Registry::new();
        let h = reg.histogram("h_seconds", &[], &bounds)?;
        let n = g.usize_in(0, 200) as u64;
        let mut sum_ns = 0u64;
        for _ in 0..n {
            let ns = g.usize_in(0, 4_000_000_000) as u64;
            sum_ns += ns;
            h.observe_ns(ns);
        }
        ensure(
            h.bucket_counts().iter().sum::<u64>() == n,
            "bucket counts must sum to the observation count",
        )?;
        ensure(h.count() == n, "count() disagrees with the bucket sum")?;
        ensure(h.sum_ns() == sum_ns, "nanosecond sum must be the exact integer total")?;
        // ...and the cumulative +Inf bucket in the exposition equals it
        let mut snap = Snapshot::new();
        reg.snapshot_into(&mut snap);
        let samples = smoothrot::telemetry::export::parse_prometheus(&snap.to_prometheus())
            .map_err(|e| format!("exposition must parse: {e}"))?;
        let inf = samples
            .iter()
            .find(|p| {
                p.name == "h_seconds_bucket"
                    && p.labels.iter().any(|(k, v)| k == "le" && v == "+Inf")
            })
            .ok_or("missing +Inf bucket")?;
        ensure(inf.value == n as f64, "cumulative +Inf bucket must equal the count")
    });
}

/// A label-safe random token (the minimal parser does not handle
/// commas or braces inside label values; real metric labels here are
/// tenant/runner/layer numbers and module names, which never need them).
fn token(g: &mut Gen) -> String {
    const ALPHABET: [char; 12] = ['a', 'b', 'c', 'k', 'q', 'v', 'x', '0', '1', '7', '_', '.'];
    (0..g.usize_in(1, 8)).map(|_| *g.choose(&ALPHABET)).collect()
}

fn random_labels(g: &mut Gen) -> Labels {
    (0..g.usize_in(0, 2)).map(|i| (format!("k{i}"), token(g))).collect()
}

fn random_snapshot(g: &mut Gen) -> Snapshot {
    let mut s = Snapshot::new();
    for i in 0..g.usize_in(0, 4) {
        s.counters.push(CounterRow {
            name: format!("c{i}_total"),
            labels: random_labels(g),
            value: g.usize_in(0, 4_000_000_000) as u64,
        });
    }
    for i in 0..g.usize_in(0, 4) {
        s.gauges.push(GaugeRow {
            name: format!("g{i}"),
            labels: random_labels(g),
            value: g.f32_in(-1e6, 1e6) as f64,
        });
    }
    for i in 0..g.usize_in(0, 2) {
        let mut le: Vec<f64> = (0..g.usize_in(1, 4)).map(|_| g.f32_in(1e-6, 4.0) as f64).collect();
        le.sort_by(f64::total_cmp);
        le.dedup();
        let counts: Vec<u64> = (0..le.len() + 1).map(|_| g.usize_in(0, 1000) as u64).collect();
        let count = counts.iter().sum();
        s.histograms.push(HistogramRow {
            name: format!("h{i}_seconds"),
            labels: random_labels(g),
            le,
            counts,
            sum: g.f32_in(0.0, 1e3) as f64,
            count,
        });
    }
    for i in 0..g.usize_in(0, 2) {
        s.difficulty.push(DifficultyRow {
            module: format!("m{i}"),
            layer: g.usize_in(0, 31),
            cell: Cell {
                count: g.usize_in(1, 1000) as u64,
                mean: g.f32_in(0.0, 10.0) as f64,
                max: g.f32_in(0.0, 10.0) as f64,
                ewma: g.f32_in(0.0, 10.0) as f64,
                err_mean: g.f32_in(0.0, 1.0) as f64,
                err_max: g.f32_in(0.0, 1.0) as f64,
                plan: g.f32_in(0.0, 10.0) as f64,
            },
        });
    }
    s
}

#[test]
fn prop_prometheus_round_trips_through_the_parser() {
    check("telemetry: exposition -> parse recovers every sample", 30, |g| {
        let s = random_snapshot(g);
        let samples = smoothrot::telemetry::export::parse_prometheus(&s.to_prometheus())
            .map_err(|e| format!("exposition must parse: {e}"))?;
        let find = |name: &str, labels: &Labels| {
            samples.iter().find(|p| p.name == name && p.labels == *labels).map(|p| p.value)
        };
        for r in &s.counters {
            ensure(
                find(&r.name, &r.labels) == Some(r.value as f64),
                format!("counter {} did not round-trip", r.name),
            )?;
        }
        for r in &s.gauges {
            // fmt_value is shortest-roundtrip Display, so parsing the
            // sample back recovers the gauge bit-exactly
            ensure(
                find(&r.name, &r.labels) == Some(r.value),
                format!("gauge {} did not round-trip", r.name),
            )?;
        }
        for r in &s.histograms {
            ensure(
                find(&format!("{}_count", r.name), &r.labels) == Some(r.count as f64),
                format!("histogram {} lost its count", r.name),
            )?;
            ensure(
                find(&format!("{}_sum", r.name), &r.labels) == Some(r.sum),
                format!("histogram {} lost its sum", r.name),
            )?;
            let mut with_inf = r.labels.clone();
            with_inf.push(("le".to_string(), "+Inf".to_string()));
            with_inf.sort();
            ensure(
                find(&format!("{}_bucket", r.name), &with_inf) == Some(r.count as f64),
                format!("histogram {} +Inf bucket must be cumulative to the count", r.name),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_json_round_trips_and_rejects_newer_schemas() {
    check("telemetry: JSON round-trip identity + version ceiling", 30, |g| {
        let s = random_snapshot(g);
        let text = s.to_json_string();
        let back = Snapshot::parse(&text).map_err(|e| format!("parse: {e}"))?;
        ensure(back == s, "JSON round-trip changed the snapshot")?;
        let needle = format!("\"version\": {TELEMETRY_SCHEMA_VERSION}");
        ensure(text.contains(&needle), "version field must serialize")?;
        let bumped = g.usize_in(TELEMETRY_SCHEMA_VERSION as usize + 1, 2_000_000);
        let newer = text.replacen(&needle, &format!("\"version\": {bumped}"), 1);
        match Snapshot::parse(&newer) {
            Ok(_) => return Err(format!("version {bumped} must be rejected")),
            Err(e) => {
                ensure(e.contains("newer than supported"), format!("wrong rejection: {e}"))?
            }
        }
        let zeroed = text.replacen(&needle, "\"version\": 0", 1);
        match Snapshot::parse(&zeroed) {
            Ok(_) => Err("version 0 must be rejected".into()),
            Err(e) => ensure(e.contains("version 0 is invalid"), format!("wrong rejection: {e}")),
        }
    });
}
